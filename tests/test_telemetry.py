"""Live telemetry HTTP plane (``repro.obs.server.TelemetryServer``).

Scrapes a real server attached to a real ``ContinuousEngine`` over
loopback: ``/metrics`` must be check_prom-clean mid-run, ``/healthz``
must flip 503 -> 200 exactly when the engine becomes ready (warmup or
first step) and back to 503 when a stuck engine misses its step
deadline, ``/requests`` must reflect the live waiting/running sets, and
``/snapshot`` must be strict JSON even on a zero-finished engine (the
NaN-TTFT regression). Also pins the lifecycle contract: 503 before
``attach()``, 404 on unknown paths, ephemeral port binding, and engine
re-attachment on one port.
"""
import json
import os
import sys
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.obs import FlightRecorder, TelemetryServer
from repro.serve import ContinuousEngine

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "tools"))
from check_prom import lint  # noqa: E402


@pytest.fixture(scope="module")
def smollm():
    cfg = get_smoke_config("smollm_135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("compute_dtype", jnp.float32)
    kw.setdefault("cache_dtype", jnp.float32)
    kw.setdefault("block_size", 4)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_running", 4)
    return ContinuousEngine(model, params, **kw)


def _prompt(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    return rng.randint(0, cfg.vocab_size, (n,)).astype(np.int32)


def _get(server, path):
    """(status, body, content-type) — HTTP errors return, not raise."""
    try:
        with urllib.request.urlopen(server.url(path), timeout=10) as r:
            return r.getcode(), r.read().decode(), r.headers.get(
                "Content-Type")
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode(), e.headers.get("Content-Type")


@pytest.fixture()
def server():
    srv = TelemetryServer(port=0)
    yield srv
    srv.close()


def _drain(eng, cfg, n_requests=2, new_tokens=3):
    for i in range(n_requests):
        eng.submit(_prompt(cfg, 5 + i, seed=i), new_tokens)
    while eng.has_work():
        eng.step()


class TestLifecycle:
    def test_ephemeral_ports_are_distinct(self, server):
        assert server.port > 0
        other = TelemetryServer(port=0)
        try:
            assert other.port != server.port
        finally:
            other.close()

    def test_503_until_attached_then_404_unknown(self, smollm, server):
        cfg, model, params = smollm
        code, body, _ = _get(server, "/metrics")
        assert code == 503 and "no engine" in json.loads(body)["error"]
        server.attach(_engine(model, params))
        code, _, _ = _get(server, "/metrics")
        assert code == 200
        code, _, _ = _get(server, "/nope")
        assert code == 404

    def test_attach_repoints_one_port(self, smollm, server):
        """One server spans the dense -> COALA engine sequence: after a
        re-attach the same port serves the new engine's registry."""
        cfg, model, params = smollm
        eng1 = _engine(model, params)
        server.attach(eng1)
        _drain(eng1, cfg, n_requests=1)
        eng2 = _engine(model, params)
        server.attach(eng2)
        _, body, _ = _get(server, "/snapshot")
        assert json.loads(body)["requests"] == 0  # eng2, not eng1


class TestEndpoints:
    def test_metrics_scrape_is_check_prom_clean(self, smollm, server):
        """The mid-run scrape is the same text CI lints from the file."""
        cfg, model, params = smollm
        eng = _engine(model, params)
        server.attach(eng)
        _drain(eng, cfg)
        code, text, ctype = _get(server, "/metrics")
        assert code == 200 and ctype == "text/plain; version=0.0.4"
        assert lint(text) == []
        assert "serve_requests_finished_total 2" in text
        assert "serve_slo_goodput" in text

    def test_snapshot_strict_json_even_zero_finished(self, smollm, server):
        cfg, model, params = smollm
        eng = _engine(model, params)
        server.attach(eng)
        code, body, _ = _get(server, "/snapshot")   # nothing finished yet
        assert code == 200
        snap = json.loads(
            body, parse_constant=lambda c: pytest.fail(f"non-strict {c}"))
        assert snap["requests"] == 0
        assert snap["mean_ttft_s"] is None
        _drain(eng, cfg)
        _, body, _ = _get(server, "/snapshot")
        snap = json.loads(
            body, parse_constant=lambda c: pytest.fail(f"non-strict {c}"))
        assert snap["requests"] == 2
        assert snap["mean_ttft_s"] > 0

    def test_requests_reflects_live_sets(self, smollm, server):
        cfg, model, params = smollm
        eng = _engine(model, params, max_running=1)
        server.attach(eng)
        eng.submit(_prompt(cfg, 5, seed=0), 8)
        eng.submit(_prompt(cfg, 6, seed=1), 8)
        eng.step()                       # admits one, queues the other
        code, body, _ = _get(server, "/requests")
        assert code == 200
        reqs = json.loads(body)
        assert len(reqs["running"]) == 1 and len(reqs["waiting"]) == 1
        run = reqs["running"][0]
        assert run["state"] == "running" and run["out_tokens"] >= 1
        assert run["prompt_tokens"] == 5 and run["ttft_s"] > 0
        assert reqs["waiting"][0]["state"] == "waiting"
        while eng.has_work():
            eng.step()
        reqs = json.loads(_get(server, "/requests")[1])
        assert reqs == {"waiting": [], "running": []}


class TestHealthz:
    def test_readiness_flips_on_first_step(self, smollm, server):
        cfg, model, params = smollm
        eng = _engine(model, params)
        server.attach(eng)
        code, body, _ = _get(server, "/healthz")
        assert code == 503
        h = json.loads(body)
        assert h["ready"] is False and h["live"] is True
        eng.submit(_prompt(cfg, 5), 8)
        eng.step()
        code, body, _ = _get(server, "/healthz")
        h = json.loads(body)
        assert code == 200 and h["ready"] is True
        assert h["last_step_age_s"] >= 0 and h["running"] == 1

    def test_readiness_via_warmed_flag(self, smollm, server):
        """Warmup completion alone (no traffic yet) marks the engine
        ready — CI polls /healthz for exactly this transition."""
        cfg, model, params = smollm
        eng = _engine(model, params)
        server.attach(eng)
        assert _get(server, "/healthz")[0] == 503
        eng.warmed = True        # warmup() sets this; avoid full compile here
        code, body, _ = _get(server, "/healthz")
        assert code == 200 and json.loads(body)["ready"] is True

    def test_liveness_trips_on_stalled_step(self, smollm, server):
        """Pending work + no step inside the deadline = not live (503),
        even though the engine was ready."""
        cfg, model, params = smollm
        eng = _engine(model, params)
        srv = TelemetryServer(eng, port=0, step_deadline_s=1e-9)
        try:
            eng.submit(_prompt(cfg, 5), 4)
            eng.step()           # ready now; deadline already blown
            code, body, _ = _get(srv, "/healthz")
            h = json.loads(body)
            assert code == 503
            assert h["ready"] is True and h["live"] is False
            while eng.has_work():
                eng.step()       # drained: idle engines are live again
            assert _get(srv, "/healthz")[0] == 200
        finally:
            srv.close()


class TestFailurePaths:
    def test_endpoint_exception_returns_500(self, smollm, server):
        class Broken:
            class registry:                      # noqa: N801 — stand-in
                @staticmethod
                def prometheus():
                    raise RuntimeError("boom")
        server.attach(Broken())
        code, body, _ = _get(server, "/metrics")
        assert code == 500 and "boom" in json.loads(body)["error"]

    def test_step_exception_dumps_postmortem(self, smollm, tmp_path,
                                             monkeypatch):
        """engine.step() raising records the event and writes the bundle
        before re-raising."""
        cfg, model, params = smollm
        fl = FlightRecorder(capacity=64,
                            dump_path=str(tmp_path / "pm.json"))
        eng = _engine(model, params, flight_recorder=fl)
        eng.submit(_prompt(cfg, 5), 2)
        monkeypatch.setattr(eng, "_step_inner",
                            lambda: (_ for _ in ()).throw(
                                RuntimeError("injected")))
        with pytest.raises(RuntimeError, match="injected"):
            eng.step()
        with open(tmp_path / "pm.json") as f:
            bundle = json.load(
                f, parse_constant=lambda c: pytest.fail(f"non-strict {c}"))
        assert bundle["reason"] == "step_exception"
        assert bundle["events"][-1]["event"] == "step_exception"
        assert "injected" in bundle["events"][-1]["error"]
        assert bundle["config"]["block_size"] == 4
