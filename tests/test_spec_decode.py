"""Self-speculative serving: draft proposal scan, chunked verifier, pool
rollback, fork seed derivation.

The load-bearing invariant is token-exactness: under greedy decoding the
speculative engine must emit byte-identical trajectories to the
non-speculative continuous engine AND to the fixed-batch oracle, for any
draft (acceptance only changes speed, never tokens) — including staggered
mixed-length traffic where rounds interleave with admissions. Warmup must
keep its zero-stall contract with the draft's scan/verify/prefill
signatures in the closed jit set. ``BlockPool.truncate`` is the rollback
primitive rejected proposals rely on; ``fork()`` must give children
distinct default seeds (the bug: children replayed the parent trajectory
at temperature > 0)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import BlockPool, ContinuousEngine, ServeEngine

MAX_LEN = 16


@pytest.fixture(scope="module")
def smollm():
    cfg = get_smoke_config("smollm_135m")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def draft_params(smollm):
    """A genuinely different draft: the same weights perturbed enough that
    verification rejects some proposals (exercising rollback + resume)."""
    _, _, params = smollm
    def perturb(path, leaf):
        if getattr(leaf, "ndim", 0) < 2:
            return leaf
        key = jax.random.PRNGKey(len(jax.tree_util.keystr(path)))
        return leaf + 0.02 * jax.random.normal(key, leaf.shape, leaf.dtype)
    return jax.tree_util.tree_map_with_path(perturb, params)


def _engine(model, params, *, draft=None, spec_k=3, **kw):
    kw.setdefault("block_size", 4)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_running", 4)
    return ContinuousEngine(model, params, compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32, draft_params=draft,
                            spec_k=spec_k, **kw)


def _staggered_trace(cfg, seed=0):
    rng = np.random.RandomState(seed)
    lens, news = [3, 9, 5, 12], [5, 3, 7, 2]
    return [(rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32), n)
            for l, n in zip(lens, news)]


class TestBlockPoolTruncate:
    def test_truncate_releases_tail_blocks(self, smollm):
        _, model, _ = smollm
        pool = BlockPool(model, num_blocks=16, block_size=4, max_requests=4,
                         dtype=jnp.float32)
        pool.alloc(1, 6)                     # 2 blocks
        pool.extend(1, 14, write_start=6)    # speculative span -> 4 blocks
        assert len(pool.table(1)) == 4
        free_before = pool.free_blocks
        pool.truncate(1, 7)                  # roll back to 7 positions
        assert len(pool.table(1)) == 2
        assert pool.free_blocks == free_before + 2
        pool.extend(1, 14, write_start=7)    # next round re-reserves
        assert len(pool.table(1)) == 4
        pool.truncate(1, 8)                  # exactly block-aligned
        assert len(pool.table(1)) == 2
        pool.free(1)

    def test_truncate_keeps_shared_blocks_alive(self, smollm):
        """Rollback on a fork must only drop the child's references; the
        parent's view of the shared blocks survives."""
        _, model, _ = smollm
        pool = BlockPool(model, num_blocks=16, block_size=4, max_requests=4,
                         dtype=jnp.float32)
        pool.alloc(1, 8)
        pool.fork(1, 2)
        pool.extend(2, 12, write_start=8)
        pool.truncate(2, 9)
        assert len(pool.table(1)) == 2       # parent untouched
        assert len(pool.table(2)) == 3
        pool.free(2)
        assert len(pool.table(1)) == 2
        pool.free(1)


class TestForkSeeds:
    def test_children_get_distinct_default_seeds(self, smollm):
        """The fork bug: with no explicit seed the child inherited the
        parent's, so every best-of-n branch replayed the same trajectory at
        temperature > 0. Children must diverge from the parent and from
        each other by default."""
        cfg, model, params = smollm
        eng = _engine(model, params, max_running=4)
        rng = np.random.RandomState(3)
        prompt = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
        pid = eng.submit(prompt, 8, temperature=1.2, seed=11)
        eng.step()
        c1 = eng.fork(pid)
        c2 = eng.fork(pid)
        fin = {r.req_id: r for r in eng.run()}
        assert fin[c1].seed != fin[pid].seed
        assert fin[c2].seed != fin[pid].seed
        assert fin[c1].seed != fin[c2].seed
        trajectories = {tuple(fin[i].out_tokens) for i in (pid, c1, c2)}
        assert len(trajectories) == 3, "forked children replayed the parent"

    def test_explicit_seed_reproduces_parent(self, smollm):
        """Passing the parent's seed explicitly keeps the old replay
        behavior available on demand."""
        cfg, model, params = smollm
        eng = _engine(model, params, max_running=4)
        rng = np.random.RandomState(4)
        prompt = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
        pid = eng.submit(prompt, 8, temperature=1.2, seed=11)
        eng.step()
        cid = eng.fork(pid, seed=11)
        fin = {r.req_id: r for r in eng.run()}
        assert fin[cid].out_tokens == fin[pid].out_tokens


class TestSpecGreedyParity:
    def test_matches_nonspec_engine_and_oracle(self, smollm, draft_params):
        """Staggered mixed-length trace: every request served speculatively
        must match both the non-speculative continuous engine and a solo
        fixed-batch run, token for token."""
        cfg, model, params = smollm
        spec = _engine(model, params, draft=draft_params, max_running=3)
        plain = _engine(model, params, max_running=3)
        leg = ServeEngine(model, params, compute_dtype=jnp.float32,
                          cache_dtype=jnp.float32)
        reqs = _staggered_trace(cfg)
        ids_s, ids_p = [], []
        for p, n in reqs:
            ids_s.append(spec.submit(p, n))
            spec.step()                      # joiners land mid-round
            ids_p.append(plain.submit(p, n))
            plain.step()
        spec.run()
        plain.run()
        fin_s = {r.req_id: r for r in spec.finished}
        fin_p = {r.req_id: r for r in plain.finished}
        for (p, n), sid, pid in zip(reqs, ids_s, ids_p):
            ref = np.asarray(leg.generate(jnp.asarray(p)[None],
                                          max_new_tokens=n))[0, len(p):]
            np.testing.assert_array_equal(
                ref, np.asarray(fin_s[sid].out_tokens),
                err_msg=f"spec request {sid} diverged from fixed-batch oracle")
            assert fin_s[sid].out_tokens == fin_p[pid].out_tokens
        m = spec.metrics()
        assert m["spec_rounds"] > 0
        assert m["spec_proposed_tokens"] > 0
        # the perturbed draft must actually exercise the rejection path
        assert m["spec_accept_rate"] < 1.0

    def test_identical_draft_accepts_everything(self, smollm):
        """draft == target: every proposal matches the verifier argmax, so
        acceptance is exactly 1.0 and eos/max-new truncation still holds."""
        cfg, model, params = smollm
        eng = _engine(model, params, draft=params)
        rng = np.random.RandomState(5)
        for n, nn in ((5, 9), (8, 6)):
            eng.submit(rng.randint(0, cfg.vocab_size, (n,)).astype(np.int32),
                       nn)
        fin = eng.run()
        assert sorted(len(r.out_tokens) for r in fin) == [6, 9]
        assert eng.metrics()["spec_accept_rate"] == 1.0

    def test_gather_path_parity(self, smollm, draft_params):
        """The gather (non-paged) read path is the in-tree oracle; the
        speculative round must be token-exact there too."""
        cfg, model, params = smollm
        spec = _engine(model, params, draft=draft_params, paged_kernel=False,
                       prefill_kernel=False)
        leg = ServeEngine(model, params, compute_dtype=jnp.float32,
                          cache_dtype=jnp.float32)
        rng = np.random.RandomState(6)
        p = rng.randint(0, cfg.vocab_size, (7,)).astype(np.int32)
        rid = spec.submit(p, 8)
        fin = {r.req_id: r for r in spec.run()}
        ref = np.asarray(leg.generate(jnp.asarray(p)[None],
                                      max_new_tokens=8))[0, 7:]
        np.testing.assert_array_equal(ref, np.asarray(fin[rid].out_tokens))

    def test_preemption_under_spec(self, smollm, draft_params):
        """A pool too small for the full load forces preemption mid-round;
        preempted requests must still finish on the greedy trajectory."""
        cfg, model, params = smollm
        spec = _engine(model, params, draft=draft_params, block_size=2,
                       num_blocks=16, max_running=3, spec_k=2)
        leg = ServeEngine(model, params, compute_dtype=jnp.float32,
                          cache_dtype=jnp.float32)
        rng = np.random.RandomState(7)
        prompts = [rng.randint(0, cfg.vocab_size, (4,)).astype(np.int32)
                   for _ in range(3)]
        ids = [spec.submit(p, 6) for p in prompts]
        fin = {r.req_id: r for r in spec.run()}
        assert sum(r.preemptions for r in fin.values()) > 0
        for p, rid in zip(prompts, ids):
            ref = np.asarray(leg.generate(jnp.asarray(p)[None],
                                          max_new_tokens=6))[0, 4:]
            np.testing.assert_array_equal(ref,
                                          np.asarray(fin[rid].out_tokens))


class TestSpecSampling:
    def test_temperature_rows_terminate_and_mix_with_greedy(self, smollm,
                                                            draft_params):
        """Greedy and sampled requests share one speculative batch; the
        greedy row stays on the deterministic trajectory and the sampled
        rows complete with the right lengths."""
        cfg, model, params = smollm
        spec = _engine(model, params, draft=draft_params)
        leg = ServeEngine(model, params, compute_dtype=jnp.float32,
                          cache_dtype=jnp.float32)
        rng = np.random.RandomState(8)
        p = rng.randint(0, cfg.vocab_size, (5,)).astype(np.int32)
        gid = spec.submit(p, 6, temperature=0.0)
        s1 = spec.submit(p, 6, temperature=1.5, seed=7)
        s2 = spec.submit(p, 6, temperature=1.5, seed=8)
        fin = {r.req_id: r for r in spec.run()}
        ref = np.asarray(leg.generate(jnp.asarray(p)[None],
                                      max_new_tokens=6))[0, 5:]
        np.testing.assert_array_equal(ref, np.asarray(fin[gid].out_tokens))
        assert len(fin[s1].out_tokens) == 6
        assert len(fin[s2].out_tokens) == 6
        # different seeds take different sampled trajectories
        assert fin[s1].out_tokens != fin[s2].out_tokens

    def test_sampled_run_is_seed_deterministic(self, smollm, draft_params):
        """Same seed, two fresh engines: the spec sampling path (in-scan
        proposal keys + host accept/bonus draws) is fully deterministic."""
        cfg, model, params = smollm
        rng = np.random.RandomState(9)
        p = rng.randint(0, cfg.vocab_size, (5,)).astype(np.int32)
        outs = []
        for _ in range(2):
            eng = _engine(model, params, draft=draft_params)
            rid = eng.submit(p, 7, temperature=1.2, seed=13)
            fin = {r.req_id: r for r in eng.run()}
            outs.append(fin[rid].out_tokens)
        assert outs[0] == outs[1]


class TestSpecWarmup:
    def test_zero_compiles_after_warmup(self, smollm, draft_params):
        """The zero-stall contract survives speculation: draft scan, verify
        chunk, and draft-params prefill all join the closed warmed set."""
        cfg, model, params = smollm
        eng = _engine(model, params, draft=draft_params, block_size=4,
                      num_blocks=24, max_running=2,
                      prefill_bucket_sizes=(8,))
        eng.warmup(max_len=MAX_LEN)
        base_decode = eng.decode_compile_count()
        base_prefill = eng.prefill_compile_count()
        rng = np.random.RandomState(10)
        mk = lambda n: rng.randint(1, cfg.vocab_size, (n,)).astype(np.int32)
        for prompt, nn in [(mk(8), 6), (mk(3), 5), (mk(10), 4), (mk(2), 6)]:
            eng.submit(prompt, nn)
            eng.step()
        eng.run()
        assert eng.post_warmup_compiles() == 0
        assert eng.decode_compile_count() == base_decode
        assert eng.prefill_compile_count() == base_prefill
        assert eng.metrics()["post_warmup_compiles"] == 0
        assert eng.metrics()["spec_rounds"] > 0


class TestSpecGuards:
    def test_spec_rejects_extras_requests(self, smollm, draft_params):
        _, model, params = smollm
        eng = _engine(model, params, draft=draft_params)
        with pytest.raises(ValueError, match="text-only"):
            eng.submit(np.zeros((4,), np.int32), 4,
                       extras={"frames": np.zeros((1, 2, 2), np.float32)})

    def test_spec_k_must_be_positive(self, smollm, draft_params):
        _, model, params = smollm
        with pytest.raises(ValueError, match="spec_k"):
            _engine(model, params, draft=draft_params, spec_k=0)
