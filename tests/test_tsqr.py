"""TSQR: streaming/tree/sequential equivalence + Gram-free guarantees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tsqr


def _x(key, n, k):
    return jax.random.normal(jax.random.PRNGKey(key), (n, k), jnp.float32)


class TestTSQR:
    def test_sequential_matches_full_qr(self):
        x = _x(0, 24, 400)
        chunks = [x.T[i:i + 64] for i in range(0, 400, 64)]
        r_seq = tsqr.tsqr_sequential(chunks)
        r_full = tsqr.qr_r(x.T)
        np.testing.assert_allclose(np.asarray(r_seq), np.asarray(r_full),
                                   rtol=1e-4, atol=1e-5)

    def test_tree_matches_full_qr(self):
        x = _x(1, 24, 512)
        chunks = [x.T[i:i + 64] for i in range(0, 512, 64)]
        r_tree = tsqr.tsqr_tree(chunks)
        np.testing.assert_allclose(np.asarray(r_tree), np.asarray(tsqr.qr_r(x.T)),
                                   rtol=1e-4, atol=1e-5)

    def test_streamer_incremental(self):
        x = _x(2, 16, 300)
        s = tsqr.RStreamer(16)
        for i in range(0, 300, 50):
            s.update(x.T[i:i + 50])
        assert s.tokens_seen == 300
        np.testing.assert_allclose(np.asarray(s.finish()),
                                   np.asarray(tsqr.square_r(tsqr.qr_r(x.T))),
                                   rtol=1e-4, atol=1e-5)

    def test_rtr_equals_gram(self):
        """RᵀR = XXᵀ — the only property Prop. 2 needs."""
        x = _x(3, 20, 256)
        r = tsqr.tsqr_sequential([x.T[i:i + 32] for i in range(0, 256, 32)])
        np.testing.assert_allclose(np.asarray(r.T @ r), np.asarray(x @ x.T),
                                   rtol=1e-3, atol=1e-3)

    def test_mu_augmentation(self):
        x = _x(4, 12, 40)
        r = tsqr.square_r(tsqr.qr_r(x.T))
        mu = 0.7
        r_aug = tsqr.augment_r_with_mu(r, mu)
        want = x @ x.T + mu * jnp.eye(12)
        np.testing.assert_allclose(np.asarray(r_aug.T @ r_aug), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_fewer_tokens_than_features(self):
        """Limited-data regime: k < n chunks still give a valid square R."""
        x = _x(5, 32, 10)
        r = tsqr.square_r(tsqr.qr_r(x.T))
        assert r.shape == (32, 32)
        np.testing.assert_allclose(np.asarray(r.T @ r), np.asarray(x @ x.T),
                                   rtol=1e-4, atol=1e-4)

    def test_gram_chunked_matches(self):
        x = _x(6, 16, 128)
        chunks = [x.T[i:i + 32] for i in range(0, 128, 32)]
        np.testing.assert_allclose(np.asarray(tsqr.gram_chunked(chunks)),
                                   np.asarray(x @ x.T), rtol=1e-4, atol=1e-4)
