"""Observability subsystem: tracer, metrics registry, numerics monitors,
and their wiring through the serving engine.

Covers the PR-6 contracts:
  * trace validity — emitted JSON parses as Chrome/Perfetto trace_event,
    spans nest strictly per thread, compile instants present;
  * golden-key schemas — ``engine.metrics()`` and ``registry.snapshot()``
    key sets are frozen so BENCH_serve.json rows can't drift silently;
  * zero-elapsed guards — ``decode_tok_per_s``/``prefill_tok_per_s`` report
    0.0 (not inf) when the steady-state timers never accumulated;
  * ``reset_metrics()`` resets every request-level series (TTFT samples,
    preemption counter, queue-wait histogram) with the registry;
  * queue observability under pool pressure — preemption counter,
    queue-wait histogram and queue-depth gauge move;
  * numerics — the cond monitor flags the cond=1e9 fixture from
    test_dist_calibrate while staying silent on well-conditioned layers,
    single-device and sharded (subprocess with 8 fake devices).
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.obs import metrics, numerics, trace
from repro.serve import ContinuousEngine

from test_dist_calibrate import run_with_devices


@pytest.fixture(scope="module")
def smollm():
    cfg = get_smoke_config("smollm_135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts and ends with the process tracer uninstalled."""
    trace.disable()
    yield
    trace.disable()


def _engine(model, params, **kw):
    kw.setdefault("compute_dtype", jnp.float32)
    kw.setdefault("cache_dtype", jnp.float32)
    kw.setdefault("block_size", 4)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_running", 4)
    return ContinuousEngine(model, params, **kw)


def _prompt(cfg, n, seed=0):
    rng = np.random.RandomState(seed)
    return rng.randint(0, cfg.vocab_size, (n,)).astype(np.int32)


def _ill_conditioned_r(n=16, k=64, cond=1e9, seed=0):
    """Upper-triangular R of an (k, n) X with the given condition number —
    the same logspace-singular-value fixture test_dist_calibrate uses."""
    rng = np.random.RandomState(seed)
    u, _ = np.linalg.qr(rng.standard_normal((k, n)))
    v, _ = np.linalg.qr(rng.standard_normal((n, n)))
    s = np.logspace(0, -np.log10(cond), n)
    x = u @ np.diag(s) @ v.T
    return np.linalg.qr(x, mode="r").astype(np.float32)


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

class TestTracer:
    def test_disabled_is_shared_noop(self):
        assert not trace.enabled()
        assert trace.span("a") is trace.span("b", x=1)
        trace.instant("nothing")                 # no-op, no error
        assert trace.save("/tmp/unused.json") == 0

    def test_span_and_instant_events(self, tmp_path):
        trace.enable()
        with trace.span("outer", a=1):
            with trace.span("inner"):
                pass
            trace.instant("tick", s=2)
        path = tmp_path / "t.json"
        assert trace.save(str(path)) == 3
        doc = json.loads(path.read_text())
        evs = [e for e in doc["traceEvents"] if e.get("ph") != "M"]
        by_name = {e["name"]: e for e in evs}
        assert by_name["inner"]["ph"] == "X"
        assert by_name["tick"]["ph"] == "i"
        # inner completes before outer and lies inside it
        out, inn = by_name["outer"], by_name["inner"]
        assert out["ts"] <= inn["ts"]
        assert inn["ts"] + inn["dur"] <= out["ts"] + out["dur"] + 1e-6
        assert out["args"] == {"a": 1}

    def test_thread_safety_and_per_thread_tids(self):
        t = trace.enable()

        barrier = threading.Barrier(4)     # idents are reused after a
                                           # thread exits; keep all 4 alive

        def work(i):
            barrier.wait()
            for _ in range(50):
                with trace.span(f"w{i}"):
                    pass

        threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        evs = t.events()
        assert len(evs) == 200
        assert len({e["tid"] for e in evs}) == 4

    def test_enable_idempotent_disable_drops(self):
        t1 = trace.enable()
        t2 = trace.enable()
        assert t1 is t2 and trace.current() is t1
        trace.disable()
        assert trace.current() is None

    def test_ring_mode_bounds_memory(self):
        """enable(max_events=N) keeps the most recent N events and counts
        the overflow in dropped; save() still emits valid JSON."""
        t = trace.enable(max_events=10)
        for i in range(25):
            t.instant(f"e{i}")
        evs = t.events()
        assert len(evs) == 10
        assert [e["name"] for e in evs] == [f"e{i}" for i in range(15, 25)]
        assert t.dropped == 15
        assert [e["name"] for e in t.tail(3)] == ["e22", "e23", "e24"]

    def test_ring_recap_in_place(self):
        """Re-enabling with an explicit cap re-caps the live tracer,
        keeping the newest events."""
        t = trace.enable()
        for i in range(8):
            t.instant(f"e{i}")
        assert trace.enable(max_events=3) is t
        assert [e["name"] for e in t.events()] == ["e5", "e6", "e7"]
        assert t.dropped == 5
        t.instant("e8")
        assert [e["name"] for e in t.events()] == ["e6", "e7", "e8"]


def _nesting_ok(events):
    """Per-tid, complete events must nest like a call stack: sorted by
    start, each next span either starts after the top ends (pop) or lies
    entirely inside it (push)."""
    by_tid = {}
    for e in events:
        if e.get("ph") == "X":
            by_tid.setdefault(e["tid"], []).append(e)
    for evs in by_tid.values():
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []
        for e in evs:
            while stack and e["ts"] >= stack[-1]["ts"] + stack[-1]["dur"]:
                stack.pop()
            if stack and e["ts"] + e["dur"] > \
                    stack[-1]["ts"] + stack[-1]["dur"] + 1e-3:
                return False                     # overlap without containment
            stack.append(e)
    return True


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_counter_gauge_histogram(self):
        reg = metrics.Registry()
        c = reg.counter("x_total")
        c.inc(); c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)
        g = reg.gauge("depth", fn=lambda: 42)
        assert g.value == 42
        with pytest.raises(ValueError):
            g.set(3)                             # callback-backed
        h = reg.histogram("lat_seconds", buckets=(0.01, 0.1, 1.0))
        for v in (0.005, 0.05, 0.5, 5.0):
            h.observe(v)
        assert h.count == 4 and h.max == 5.0
        assert h.quantile(0.5) == 0.1
        assert h.quantile(1.0) == 5.0            # overflow capped at max

    def test_strict_registration(self):
        reg = metrics.Registry()
        reg.counter("a_total")
        with pytest.raises(ValueError):
            reg.counter("a_total")               # duplicate
        with pytest.raises(ValueError):
            reg.gauge("bad name")                # illegal chars
        with pytest.raises(ValueError):
            reg.histogram("h", buckets=(1.0, 0.5))   # not increasing

    def test_log_buckets(self):
        b = metrics.log_buckets(1e-3, 1.0, per_decade=1)
        assert b[0] == pytest.approx(1e-3)
        assert b[-1] >= 1.0
        assert all(y > x for x, y in zip(b, b[1:]))

    def test_snapshot_and_reset(self):
        reg = metrics.Registry()
        c = reg.counter("n_total")
        h = reg.histogram("t_seconds", buckets=(1.0, 10.0))
        g = reg.gauge("live", fn=lambda: 7)
        c.inc(3); h.observe(0.5)
        snap = reg.snapshot()
        assert snap["n_total"] == 3
        assert snap["t_seconds_count"] == 1
        assert snap["live"] == 7
        reg.reset()
        snap = reg.snapshot()
        assert snap["n_total"] == 0 and snap["t_seconds_count"] == 0
        assert snap["live"] == 7                 # callback gauges read live

    def test_prometheus_exposition_lints_clean(self):
        import os
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), "tools"))
        from check_prom import lint
        reg = metrics.Registry()
        reg.counter("req_total", "requests").inc(5)
        reg.gauge("depth", "queue depth").set(2)
        h = reg.histogram("lat_seconds", buckets=(0.1, 1.0), help="latency")
        h.observe(0.05); h.observe(3.0)
        text = reg.prometheus()
        assert lint(text) == []
        assert 'lat_seconds_bucket{le="+Inf"} 2' in text
        assert "# TYPE req_total counter" in text


# ---------------------------------------------------------------------------
# Engine wiring: schemas, guards, reset, queue observability, trace spans
# ---------------------------------------------------------------------------

# frozen compatibility schema of engine.metrics() — BENCH_serve.json rows
# read these keys; extend deliberately, never let them drift silently
METRICS_KEYS = {
    "requests", "requests_per_sec", "new_tokens", "tokens_per_sec",
    "mean_ttft_s", "max_ttft_s", "preemptions",
    "decode_compiles", "decode_shapes", "decode_steps", "decode_tok_per_s",
    "prefill_compiles", "prefill_shapes", "prefill_batches",
    "prefill_tok_per_s", "prefill_kernel",
    "prefix_hit_rate", "prefix_hit_tokens", "cached_blocks",
    "cow_copies", "prefix_evictions", "queue_depth",
    "warmup_seconds", "post_warmup_compiles", "slo_goodput",
}

# frozen registry series names (snapshot() expands histograms with these
# suffixes: _count/_sum/_mean/_p50/_p99/_max)
REGISTRY_NAMES = {
    "serve_decode_steps_total", "serve_decode_tokens_total",
    "serve_decode_seconds_total", "serve_prefill_batches_total",
    "serve_prefill_tokens_total", "serve_prefill_seconds_total",
    "serve_prompt_tokens_total", "serve_prefix_hit_tokens_total",
    "serve_requests_finished_total", "serve_new_tokens_total",
    "serve_ttft_seconds", "serve_decode_step_seconds",
    "serve_tpot_seconds", "serve_request_e2e_seconds",
    "serve_slo_goodput",
    "serve_running_requests", "serve_decode_compiles",
    "serve_prefill_compiles",
    "serve_warmup_seconds", "serve_post_warmup_compiles",
    "serve_queue_depth", "serve_queue_wait_seconds",
    "serve_requests_admitted_total", "serve_preemptions_total",
    "pool_cow_copies_total", "pool_prefix_evictions_total",
    "pool_free_blocks", "pool_cached_blocks",
}


class TestEngineWiring:
    def test_metrics_golden_keys(self, smollm):
        cfg, model, params = smollm
        eng = _engine(model, params)
        assert set(eng.metrics()) == METRICS_KEYS       # empty engine
        eng.submit(_prompt(cfg, 6), 3)
        while eng.has_work():
            eng.step()
        assert set(eng.metrics()) == METRICS_KEYS       # after serving

    def test_registry_golden_names(self, smollm):
        _, model, params = smollm
        eng = _engine(model, params)
        assert set(eng.registry.names()) == REGISTRY_NAMES
        hist_names = {n for n in REGISTRY_NAMES
                      if isinstance(eng.registry.get(n), metrics.Histogram)}
        snap = eng.registry.snapshot()
        expect = (REGISTRY_NAMES - hist_names) | {
            f"{n}{suf}" for n in hist_names
            for suf in ("_count", "_sum", "_mean", "_p50", "_p99", "_max")}
        assert set(snap) == expect

    def test_zero_elapsed_rates_are_zero(self, smollm):
        """A single-step trace compiles on every step, so the steady-state
        timers never accumulate — rates must report 0.0, not inf."""
        cfg, model, params = smollm
        eng = _engine(model, params)
        eng.submit(_prompt(cfg, 6), 2)
        eng.step()                                # prefill + 1st decode: all
        m = eng.metrics()                         # signatures fresh
        assert m["decode_tok_per_s"] == 0.0
        assert m["prefill_tok_per_s"] == 0.0
        assert np.isfinite(m["decode_tok_per_s"])
        # prometheus exposition must stay float-clean too
        assert "inf" not in eng.registry.prometheus()

    def test_metrics_strict_json_on_zero_finished_runs(self, smollm):
        """Regression: metrics() used to emit float('nan') for mean/max
        TTFT before anything finished, which json.dumps turns into
        non-strict NaN literals that the /snapshot endpoint (and any strict
        parser) rejects. Undefined TTFT is None now, in every branch."""
        cfg, model, params = smollm
        eng = _engine(model, params)
        m = eng.metrics()                         # nothing submitted
        assert m["mean_ttft_s"] is None and m["max_ttft_s"] is None
        assert m["tokens_per_sec"] == 0.0
        json.loads(json.dumps(m, allow_nan=False))
        eng.submit(_prompt(cfg, 6), 3)
        eng.step()                                # in flight, none finished
        if not eng.finished:
            assert eng.metrics()["mean_ttft_s"] is None
        while eng.has_work():
            eng.step()
        m = eng.metrics()
        assert m["mean_ttft_s"] is not None and m["mean_ttft_s"] >= 0.0
        json.loads(json.dumps(m, allow_nan=False))

    def test_slo_accounting(self, smollm):
        """TPOT/e2e histograms fill at _finish and the goodput gauge grades
        finished requests against the configured SLOs: impossible SLOs give
        0.0, generous ones 1.0, none (or nothing finished) reads 1.0."""
        cfg, model, params = smollm
        eng = _engine(model, params, slo_ttft_s=1e-9, slo_tpot_s=1e-9)
        assert eng.metrics()["slo_goodput"] == 1.0     # vacuous: none done
        for i in range(2):
            eng.submit(_prompt(cfg, 6, seed=i), 4)
        while eng.has_work():
            eng.step()
        m = eng.metrics()
        assert m["slo_goodput"] == 0.0                 # nothing beats 1ns
        assert eng.registry.get("serve_slo_goodput").value == 0.0
        assert eng.registry.get("serve_tpot_seconds").count == 2
        assert eng.registry.get("serve_request_e2e_seconds").count == 2
        assert eng.registry.get("serve_tpot_seconds").max > 0.0
        # generous SLOs: everything meets them
        eng2 = _engine(model, params, slo_ttft_s=3600.0, slo_tpot_s=3600.0)
        eng2.submit(_prompt(cfg, 6), 4)
        while eng2.has_work():
            eng2.step()
        assert eng2.metrics()["slo_goodput"] == 1.0
        # reset drops the finished list, so the gauge reads vacuous again
        eng.reset_metrics()
        assert eng.registry.get("serve_slo_goodput").value == 1.0
        assert eng.registry.get("serve_tpot_seconds").count == 0

    def test_reset_metrics_resets_request_level_stats(self, smollm):
        cfg, model, params = smollm
        eng = _engine(model, params)
        for i in range(3):
            eng.submit(_prompt(cfg, 6, seed=i), 4)
        while eng.has_work():
            eng.step()
        assert eng.metrics()["requests"] == 3
        assert eng.registry.get("serve_ttft_seconds").count == 3
        eng.reset_metrics()
        m = eng.metrics()
        assert m["requests"] == 0
        assert m["preemptions"] == 0
        assert m["mean_ttft_s"] is None           # TTFT samples gone
        snap = eng.registry.snapshot()
        assert snap["serve_ttft_seconds_count"] == 0
        assert snap["serve_queue_wait_seconds_count"] == 0
        assert snap["serve_requests_finished_total"] == 0
        # shape caches stay warm: reset is for steady-state benching
        assert eng.metrics()["decode_shapes"] > 0

    def test_queue_observability_under_pool_pressure(self, smollm):
        """A pool too small for the full load: requests queue (depth gauge,
        wait histogram) and the running set preempts (counter)."""
        cfg, model, params = smollm
        eng = _engine(model, params, block_size=2, num_blocks=9,
                      max_running=3)
        for i in range(4):
            eng.submit(_prompt(cfg, 4, seed=i), 6)
        # before any step everything waits: the live gauge reads the queue
        assert eng.registry.get("serve_queue_depth").value == 4
        assert eng.metrics()["queue_depth"] == 4
        depth_seen = []
        while eng.has_work():
            eng.step()
            depth_seen.append(eng.registry.get("serve_queue_depth").value)
        m = eng.metrics()
        assert m["requests"] == 4
        assert m["preemptions"] >= 1
        assert eng.registry.get("serve_preemptions_total").value >= 1
        # every admission (including re-admissions) observed a queue wait
        qw = eng.registry.get("serve_queue_wait_seconds")
        assert qw.count == \
            eng.registry.get("serve_requests_admitted_total").value
        assert qw.count >= 4 + m["preemptions"]
        assert qw.max > 0.0
        assert depth_seen[-1] == 0                # drained

    def test_trace_validity_over_served_load(self, smollm, tmp_path):
        """Serve a real mixed load with tracing on: the JSON parses, the
        expected span taxonomy is present, compile instants fire, and spans
        nest stack-like per thread."""
        cfg, model, params = smollm
        trace.enable()
        eng = _engine(model, params)
        for i in range(3):
            eng.submit(_prompt(cfg, 5 + 3 * i, seed=i), 4)
        while eng.has_work():
            eng.step()
        path = tmp_path / "serve_trace.json"
        n = trace.save(str(path))
        assert n > 0
        doc = json.loads(path.read_text())
        assert doc["displayTimeUnit"] == "ms"
        evs = doc["traceEvents"]
        names = {e["name"] for e in evs}
        assert {"serve.admit", "serve.prefill_batch",
                "serve.decode_step"} <= names
        assert "serve.decode_compile" in names    # instant events
        for e in evs:
            assert e["ph"] in ("X", "i", "M")
            if e["ph"] == "X":
                assert e["dur"] >= 0 and "tid" in e
        assert _nesting_ok(evs)

    def test_tracing_off_leaves_no_events(self, smollm):
        cfg, model, params = smollm
        eng = _engine(model, params)
        eng.submit(_prompt(cfg, 6), 2)
        while eng.has_work():
            eng.step()
        assert not trace.enabled()
        assert trace.save("/tmp/unused.json") == 0


# ---------------------------------------------------------------------------
# Numerics monitors
# ---------------------------------------------------------------------------

class TestNumerics:
    def test_flags_ill_conditioned_silent_on_well_conditioned(self):
        rs = {"bad": _ill_conditioned_r(cond=1e9),
              "good": _ill_conditioned_r(cond=1e3, seed=1),
              "warn": _ill_conditioned_r(cond=3e6, seed=2)}
        tokens = {p: 64 for p in rs}
        by = {h.path: h for h in numerics.check_r_factors(rs, tokens)}
        assert by["bad"].level == "fail"
        assert 1e8 < by["bad"].cond < 1e11     # cond1 within ~n of cond2
        assert by["good"].level == "ok" and not by["good"].reasons
        assert by["warn"].level == "warn"
        assert numerics.worst_level(list(by.values())) == "fail"

    def test_insufficient_data_flagged(self):
        r = _ill_conditioned_r(n=16, k=64, cond=1e2)
        by = {h.path: h
              for h in numerics.check_r_factors({"x": r}, {"x": 8})}
        assert by["x"].level in ("warn", "fail")
        assert any("insufficient data" in r for r in by["x"].reasons)

    def test_singular_r_is_inf_and_fails(self):
        r = np.triu(np.ones((8, 8), np.float32))
        r[3, 3] = 0.0                             # rank-deficient
        assert numerics.triangular_cond(r) == float("inf")
        h = numerics.check_r_factors({"x": r})[0]
        assert h.level == "fail"

    def test_triangular_cond_matches_dense_estimate(self):
        r = _ill_conditioned_r(n=12, k=48, cond=1e4, seed=3)
        est = numerics.triangular_cond(r)
        ref = np.linalg.cond(r, p=1)
        assert est == pytest.approx(ref, rel=1e-3)

    def test_calibrator_duck_type(self, smollm):
        cfg, model, params = smollm
        from repro.core.calibrate import calibrate_model
        from repro.data import DataConfig, TokenPipeline
        pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=32, global_batch=4), cfg)
        cal = calibrate_model(model, params, [pipe.get_batch(0)])
        healths = numerics.check_calibration(cal)
        assert healths and all(h.tokens is not None for h in healths)
        report = numerics.format_report(healths)
        assert "layers checked" in report

    def test_residual_vs_bound_grading(self):
        class Rep:
            def __init__(self, path, res, bound):
                self.path = path
                self.rel_err_weighted = res
                self.rel_err_bound = bound
        reports = [Rep("tight", 0.105, 0.10),     # 1.05x: ok
                   Rep("loose", 0.5, 0.10),       # 5x: warn
                   Rep("broken", 2.0, 0.10),      # 20x: fail
                   Rep("no_rf", float("nan"), float("nan"))]
        by = {h.path: h for h in numerics.check_compression(reports)}
        assert set(by) == {"tight", "loose", "broken"}   # nan skipped
        assert by["tight"].level == "ok"
        assert by["loose"].level == "warn"
        assert by["broken"].level == "fail"

    def test_compress_reports_carry_bound(self, smollm):
        """compress_params emits rel_err_bound <= rel_err_weighted (the
        bound is the attainable optimum) and finite for calibrated layers."""
        cfg, model, params = smollm
        from repro.config import CompressConfig
        from repro.core.calibrate import calibrate_model
        from repro.core.compress import compress_model
        from repro.data import DataConfig, TokenPipeline
        pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=32, global_batch=4), cfg)
        cal = calibrate_model(model, params, [pipe.get_batch(0)])
        _, reports = compress_model(
            model, params, cal,
            CompressConfig(method="coala", ratio=0.6, lam=4.0, mu=-1.0))
        assert reports
        for rep in reports:
            assert np.isfinite(rep.rel_err_bound)
            assert rep.rel_err_bound <= rep.rel_err_weighted * (1 + 1e-4)

    def test_sharded_calibration_monitor_parity(self):
        """The cond monitor must reach the same verdicts through the
        sharded butterfly-reduce path as single-device: ill-conditioned
        synthetic activations flagged, well-conditioned silent — on the
        cond=1e9 fixture from test_dist_calibrate."""
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.core.calibrate import Calibrator
            from repro.dist.calibrate import ShardedCalibration, \\
                combine_r_shards
            from repro.core.tsqr import square_r
            from repro.obs import numerics

            def x_with_cond(n, k, cond, seed):
                rng = np.random.RandomState(seed)
                u, _ = np.linalg.qr(rng.standard_normal((k, n)))
                v, _ = np.linalg.qr(rng.standard_normal((n, n)))
                s = np.logspace(0, -np.log10(cond), n)
                return (u @ np.diag(s) @ v.T).astype(np.float32)

            n, k, shards = 16, 512, 8
            mesh = jax.make_mesh((shards,), ("data",),
                                 devices=jax.devices()[:shards],
                                 axis_types=(jax.sharding.AxisType.Auto,))
            cases = {"bad": 1e9, "good": 1e3}
            factors, tokens = {}, {}
            single = Calibrator()
            for seed, (path, cond) in enumerate(cases.items()):
                x = x_with_cond(n, k, cond, seed=seed)
                single.record(path, jnp.asarray(x))
                per = k // shards
                locs = []
                for s_i in range(shards):
                    c = Calibrator()
                    c.record(path, jnp.asarray(x[s_i*per:(s_i+1)*per]))
                    locs.append(square_r(c.streams[path].r))
                factors[path] = combine_r_shards(jnp.stack(locs), mesh)
                tokens[path] = k
            sharded = ShardedCalibration(factors=factors, tokens=tokens,
                                         n_shards=shards)
            for name, cal in (("single", single), ("sharded", sharded)):
                by = {h.path: h for h in numerics.check_calibration(cal)}
                assert by["bad"].level == "fail", (name, by["bad"])
                assert by["good"].level == "ok", (name, by["good"])
                print(name, "bad=%.3e" % by["bad"].cond,
                      "good=%.3e" % by["good"].cond)
            print("MONITOR_PARITY_OK")
        """)
        assert "MONITOR_PARITY_OK" in out
