"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(shape, key, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


class TestLowrankLinear:
    @pytest.mark.slow
    @pytest.mark.parametrize("m,d_in,r,d_out", [
        (256, 512, 128, 512), (512, 256, 128, 1024),
        (256, 128, 128, 128), (300, 200, 64, 150),   # fallback path (non-divisible)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, d_in, r, d_out, dtype):
        x = _rand((m, d_in), 0, dtype)
        b_t = _rand((d_in, r), 1, dtype)
        a_t = _rand((r, d_out), 2, dtype)
        got = ops.lowrank_linear(x, b_t, a_t, block_m=128, block_n=128)
        want = ref.lowrank_linear_ref(x, b_t, a_t)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol * 10)

    def test_batched_input(self):
        x = _rand((2, 32, 256), 3)
        b_t, a_t = _rand((256, 128), 4), _rand((128, 256), 5)
        got = ops.lowrank_linear(x, b_t, a_t, block_m=64, block_n=128)
        want = ref.lowrank_linear_ref(x, b_t, a_t)
        assert got.shape == (2, 32, 256)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestGramAccum:
    @pytest.mark.slow
    @pytest.mark.parametrize("k,n", [(1024, 256), (512, 512), (100, 96)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, k, n, dtype):
        a = _rand((k, n), 6, dtype)
        got = ops.gram_accum(a, block_i=128, block_j=128, block_k=256)
        want = ref.gram_accum_ref([a])
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol)

    def test_chunked_sum_equals_full(self):
        a = _rand((2048, 128), 7)
        g = sum(ops.gram_accum(a[i:i + 512], block_k=256)
                for i in range(0, 2048, 512))
        np.testing.assert_allclose(np.asarray(g), np.asarray(ref.gram_accum_ref([a])),
                                   rtol=1e-3, atol=1e-3)


class TestFlashAttention:
    @pytest.mark.slow
    @pytest.mark.parametrize("b,t,hq,hkv,hd", [
        (1, 256, 4, 4, 64),            # MHA
        (2, 256, 8, 2, 64),            # GQA 4:1
        (1, 512, 4, 1, 128),           # MQA
        (1, 192, 3, 1, 64),            # fallback path (non-divisible)
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, t, hq, hkv, hd, dtype):
        q = _rand((b, t, hq, hd), 8, dtype)
        k = _rand((b, t, hkv, hd), 9, dtype)
        v = _rand((b, t, hkv, hd), 10, dtype)
        got = ops.flash_attention(q, k, v, block_q=128, block_k=128)
        want = ref.flash_attention_ref(q, k, v)
        tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_softcap(self):
        q = _rand((1, 256, 4, 64), 11)
        k = _rand((1, 256, 4, 64), 12)
        v = _rand((1, 256, 4, 64), 13)
        got = ops.flash_attention(q, k, v, cap=20.0)
        want = ref.flash_attention_ref(q, k, v, cap=20.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_matches_model_chunked_path(self):
        """The model's chunked jnp attention and the kernel agree."""
        from repro.models.attention import _chunked_sdpa
        q = _rand((1, 512, 4, 64), 14)
        k = _rand((1, 512, 2, 64), 15)
        v = _rand((1, 512, 2, 64), 16)
        got = ops.flash_attention(q, k, v)
        want = _chunked_sdpa(q, k, v, q_offset=0, causal=True, window=0,
                             cap=0.0, scale=64 ** -0.5, chunk_q=128, chunk_kv=128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


class TestModelPallasPath:
    @pytest.mark.slow
    def test_model_forward_with_pallas_attention(self):
        """A whole-model forward through the Pallas flash kernel (interpret
        mode) matches the portable attention path."""
        import dataclasses
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.models.common import ParallelCtx
        cfg = get_smoke_config("olmo_1b")
        cfg = dataclasses.replace(cfg, head_dim=16)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 128),
                                              0, cfg.vocab_size)}
        l_ref, _ = model.loss(params, batch, ctx=ParallelCtx(),
                              compute_dtype=jnp.float32)
        l_pal, _ = model.loss(params, batch, ctx=ParallelCtx(use_pallas=True),
                              compute_dtype=jnp.float32)
        np.testing.assert_allclose(float(l_pal), float(l_ref),
                                   rtol=1e-4, atol=1e-4)
