"""Live-traffic recalibration: capture parity, bound-gated hot swaps, and
the no-retrace / no-drain serving invariants.

The contract under test (serve/recalibrate.py + ContinuousEngine.hot_swap):

  * capture parity — the R factors a ``TrafficCalibrator`` accumulates from
    a served trace equal (as RᵀR) an offline ``Calibrator`` fed the same
    sampled token streams: incremental position-sliced capture is exactly
    causal replay;
  * swap exactness — ``hot_swap`` is a pure value swap: swapping factors
    bitwise-identical to the live ones must not perturb a single token of
    any in-flight or future request;
  * zero retraces — rank-pinned recompression keeps every factor's
    shape/dtype, so a swap after ``warmup()`` leaves
    ``post_warmup_compiles() == 0``;
  * gating — no swap ships before the data gate clears, and rank-unstable
    or treedef-changing params are rejected loudly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CompressConfig
from repro.configs import get_smoke_config
from repro.core.calibrate import Calibrator
from repro.core.compress import compress_model, rank_map_from_reports
from repro.models import build_model
from repro.obs import numerics
from repro.serve import (ContinuousEngine, RecalibPolicy, RecalibWorker,
                         TrafficCalibrator)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm_135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    cal = Calibrator()
    for _ in range(3):
        batch = {"tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size, (2, 32)))}
        model.capture_forward(params, batch, cal)
    ccfg = CompressConfig(method="coala", ratio=0.6, lam=4.0, mu=-1.0)
    cparams, reports = compress_model(model, params, cal, ccfg)
    return cfg, model, params, ccfg, cparams, rank_map_from_reports(reports)


def _engine(model, params, **kw):
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_running", 4)
    return ContinuousEngine(model, params, compute_dtype=jnp.float32,
                           cache_dtype=jnp.float32, **kw)


def _trace(cfg, n=4, seed=1):
    rng = np.random.RandomState(seed)
    return [(2 * i, rng.randint(0, cfg.vocab_size, (6 + 5 * i,)), 10)
            for i in range(n)]


def _serve(eng, trace):
    pending = list(trace)
    step = 0
    while pending or eng.has_work():
        while pending and pending[0][0] <= step:
            _, prompt, nn = pending.pop(0)
            eng.submit(prompt, nn)
        eng.step()
        step += 1
    eng.flush_stream()
    return {r.req_id: list(r.out_tokens) for r in eng.finished}


def _attach(eng, model, params, ccfg, rank_map, **pol):
    pol.setdefault("check_every", 1)
    pol.setdefault("min_new_tokens", 8)
    cal = TrafficCalibrator(model, policy=RecalibPolicy(**pol))
    worker = RecalibWorker(model, params, cal, ccfg, rank_map=rank_map)
    eng.attach_recalibrator(worker)
    return worker


# ------------------------------------------------------------------ parity
def test_traffic_r_matches_offline_replay(setup):
    """The tentpole parity claim: traffic-captured R == offline Calibrator
    fed the same sampled streams, as RᵀR, to fp32 roundoff. Causality makes
    the incremental (prompt-at-admission + tail-at-completion) capture an
    exact replay of full-stream capture."""
    cfg, model, params, ccfg, cparams, rank_map = setup
    eng = _engine(model, cparams)
    worker = _attach(eng, model, params, ccfg, rank_map,
                     min_token_factor=1e9)      # collect only, never swap
    _serve(eng, _trace(cfg))
    cal = worker.cal
    assert cal.sampled_requests == 4 and cal.captured_streams
    offline = Calibrator()
    for stream in cal.captured_streams:
        model.capture_forward(params, {"tokens": jnp.asarray(stream)[None]},
                              offline)
    rf_t, rf_o = cal.r_factors(), offline.r_factors()
    assert set(rf_t) == set(rf_o)
    assert cal.tokens_seen() == offline.tokens_seen()
    for p in rf_o:
        g_t, g_o = rf_t[p].T @ rf_t[p], rf_o[p].T @ rf_o[p]
        rel = float(jnp.linalg.norm(g_t - g_o) / jnp.linalg.norm(g_o))
        assert rel < 1e-4, (p, rel)


def test_incremental_capture_counts_positions_once(setup):
    """Re-admission after preemption must resume from captured_upto: a
    second on_prefill over a longer stream adds only the new positions."""
    cfg, model, params, ccfg, cparams, rank_map = setup
    cal = TrafficCalibrator(model, policy=RecalibPolicy())

    class Req:
        req_id = 7
        prompt = np.arange(6, dtype=np.int32) % cfg.vocab_size
        out_tokens = []

        def prefill_tokens(self):
            return np.concatenate(
                [self.prompt, np.asarray(self.out_tokens, np.int32)])

    req = Req()
    cal.on_prefill(params, req)
    assert cal.captured_tokens == 6
    req.out_tokens = [1, 2, 3]           # preempted after 3 tokens, resumed
    cal.on_prefill(params, req)
    assert cal.captured_tokens == 9      # only the 3 new positions
    req.out_tokens = [1, 2, 3, 4, 5]
    cal.on_finish(params, req)           # tail: out[:-1] past captured_upto
    assert cal.captured_tokens == 10
    assert set(cal.tokens_seen().values()) == {10}
    (stream,) = cal.captured_streams
    np.testing.assert_array_equal(
        stream, np.concatenate([req.prompt, [1, 2, 3, 4]]))


# ------------------------------------------------------- swap exactness
def test_identity_hot_swap_is_token_exact(setup):
    """Swapping in bitwise-identical factors mid-trace must not change any
    token of any request — in-flight requests keep their KV pages and the
    output stream equals a never-swapped engine's exactly."""
    cfg, model, params, ccfg, cparams, rank_map = setup
    ref = _serve(_engine(model, cparams), _trace(cfg))

    eng = _engine(model, cparams)
    swaps = 0
    pending = list(_trace(cfg))
    step = 0
    while pending or eng.has_work():
        while pending and pending[0][0] <= step:
            _, prompt, nn = pending.pop(0)
            eng.submit(prompt, nn)
        eng.step()
        if eng.scheduler.running:        # swap while requests are in flight
            eng.hot_swap(jax.tree.map(jnp.copy, eng.params))
            swaps += 1
        step += 1
    eng.flush_stream()
    assert swaps > 0
    got = {r.req_id: list(r.out_tokens) for r in eng.finished}
    assert got == ref


def test_real_swap_mid_trace_no_retrace(setup):
    """A genuine bound-cleared recompression swap lands while requests are
    in flight, every request still runs to completion, and the swap causes
    zero post-warmup compiles (rank-stable shapes hit the live jit cache)."""
    cfg, model, params, ccfg, cparams, rank_map = setup
    eng = _engine(model, cparams)
    trace = _trace(cfg)
    eng.warmup(max_len=max(len(p) + nn for _, p, nn in trace))
    worker = _attach(eng, model, params, ccfg, rank_map)
    in_flight_at_swap = -1
    pending = list(trace)
    step = 0
    while pending or eng.has_work():
        while pending and pending[0][0] <= step:
            _, prompt, nn = pending.pop(0)
            eng.submit(prompt, nn)
        eng.step()
        if worker.swaps and in_flight_at_swap < 0:
            in_flight_at_swap = len(eng.scheduler.running)
        step += 1
    eng.flush_stream()
    assert worker.swaps >= 1, worker.summary()
    assert in_flight_at_swap > 0, "swap landed with no requests in flight"
    assert worker.last_excess <= worker.policy.max_residual_excess
    assert eng.post_warmup_compiles() == 0
    assert len(eng.finished) == len(trace)
    assert all(len(r.out_tokens) == r.max_new_tokens for r in eng.finished)


def test_hot_swap_rejects_shape_and_treedef_changes(setup):
    """Rank-unstable factors (different shapes) or a different pytree
    structure must be rejected before touching the live params."""
    cfg, model, params, ccfg, cparams, rank_map = setup
    eng = _engine(model, cparams)
    live = eng.params
    # shape change: truncate one rank dimension of one factored leaf
    bad = jax.tree.map(
        lambda a: a[..., :-1] if a.ndim == 3 and a.shape[-1] > 1 else a,
        cparams)
    with pytest.raises(ValueError, match="shape/dtype"):
        eng.hot_swap(bad)
    # treedef change: dense params have {'w'} where factored have
    # {'a_t','b_t'}
    with pytest.raises(ValueError, match="treedef"):
        eng.hot_swap(params)
    # draft swap without speculative mode
    with pytest.raises(ValueError, match="speculative"):
        eng.hot_swap(cparams, cparams)
    assert eng.params is live


# ----------------------------------------------------------------- gating
def test_no_swap_before_data_gate_clears(setup):
    """With an unreachable min_token_factor the worker keeps collecting:
    no solve is attempted and the served output equals a plain engine's."""
    cfg, model, params, ccfg, cparams, rank_map = setup
    ref = _serve(_engine(model, cparams), _trace(cfg))
    eng = _engine(model, cparams)
    worker = _attach(eng, model, params, ccfg, rank_map,
                     min_token_factor=1e9)
    got = _serve(eng, _trace(cfg))
    assert worker.swaps == 0 and worker.solve_attempts == 0
    assert worker.last_status == "collecting"
    assert 0.0 <= worker.clearance() < 1.0
    assert got == ref


def test_sampling_rate_zero_captures_nothing(setup):
    cfg, model, params, ccfg, cparams, rank_map = setup
    eng = _engine(model, cparams)
    worker = _attach(eng, model, params, ccfg, rank_map, sample_rate=0.0)
    _serve(eng, _trace(cfg))
    assert worker.cal.sampled_requests == 0
    assert worker.cal.captured_tokens == 0
    assert worker.swaps == 0 and worker.clearance() == 0.0


def test_augmented_cond_gate_uses_mu(setup):
    """The conditioning gate grades the μ-augmented R̃ (Prop. 3), not the
    raw R: with fewer streamed tokens than features the raw R is singular
    by construction (cond = inf, permanent FAIL) while R̃ is well-posed."""
    rng = np.random.RandomState(0)
    n, t = 16, 7                          # t < n: insufficient-data regime
    cal = Calibrator()
    cal.record("layer", jnp.asarray(rng.randn(t, n), jnp.float32))
    rf = cal.r_factors()
    raw = numerics.check_r_factors(rf)
    assert raw[0].cond == float("inf") and raw[0].level == numerics.FAIL
    aug = numerics.check_augmented_r_factors(rf, {"layer": 1e-2})
    assert np.isfinite(aug[0].cond)
    assert aug[0].level != numerics.FAIL
    # μ <= 0 falls back to grading the raw factor
    aug0 = numerics.check_augmented_r_factors(rf, {"layer": 0.0})
    assert aug0[0].cond == float("inf")


# ---------------------------------------------------------------- metrics
def test_recalib_metrics_only_when_attached(setup):
    """metrics()/registry schema is frozen for plain engines; the
    serve_recalib_* series appear only after attach_recalibrator."""
    cfg, model, params, ccfg, cparams, rank_map = setup
    plain = _engine(model, cparams)
    assert not any("recalib" in k for k in plain.metrics())
    assert not any("recalib" in n for n in plain.registry.snapshot())

    eng = _engine(model, cparams)
    worker = _attach(eng, model, params, ccfg, rank_map)
    _serve(eng, _trace(cfg))
    m = eng.metrics()
    assert m["recalib_swaps"] == worker.swaps >= 1
    assert m["recalib_sampled_requests"] == 4
    assert m["recalib_captured_tokens"] == worker.cal.captured_tokens > 0
    assert m["recalib_clearance"] >= 1.0
    assert np.isfinite(m["recalib_residual_excess"])
    snap = eng.registry.snapshot()
    assert snap["serve_recalib_swaps_total"] == worker.swaps
    assert snap["serve_recalib_captured_tokens_total"] == \
        worker.cal.captured_tokens
    assert snap["serve_recalib_sampled_requests_total"] == 4
    assert snap["serve_recalib_tokens_seen_min"] == worker.min_tokens_seen()
    assert snap["serve_recalib_bound_clearance"] == pytest.approx(
        worker.clearance())


def test_worker_rejects_empty_rank_map(setup):
    cfg, model, params, ccfg, cparams, rank_map = setup
    cal = TrafficCalibrator(model, policy=RecalibPolicy())
    with pytest.raises(ValueError, match="rank_map"):
        RecalibWorker(model, params, cal, ccfg, rank_map={})
    with pytest.raises(ValueError, match="draft_rank_map"):
        RecalibWorker(model, params, cal, ccfg, rank_map=rank_map,
                      draft_ratio=0.4)


def test_rank_map_recompression_is_shape_stable(setup):
    """compress_model with a pinned rank_map reproduces the exact factor
    shapes/dtypes of the original compression from different calibration
    data — the invariant hot swaps depend on."""
    cfg, model, params, ccfg, cparams, rank_map = setup
    rng = np.random.RandomState(9)
    cal2 = Calibrator()
    model.capture_forward(
        params, {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size,
                                                   (1, 40)))}, cal2)
    re_params, re_reports = compress_model(model, params, cal2, ccfg,
                                           rank_map=rank_map)
    assert jax.tree.structure(re_params) == jax.tree.structure(cparams)
    for a, b in zip(jax.tree.leaves(re_params), jax.tree.leaves(cparams)):
        assert a.shape == b.shape and a.dtype == b.dtype
    assert {r.path: r.rank for r in re_reports
            if r.path in rank_map} == rank_map
