"""Exposition linter (tools/check_prom.py): every failure class goes red.

CI trusts this linter on both the exit-written metrics file and the live
``/metrics`` scrape, so each check must demonstrably fire — especially
the HELP-coverage classes added with the telemetry plane: a TYPE-declared
family with no ``# HELP``, an empty HELP string, a malformed HELP line,
and a duplicated one. Pure text fixtures, no engine: the real-registry
green path lives in tests/test_obs.py and tests/test_telemetry.py.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "tools"))
from check_prom import lint  # noqa: E402

VALID = """\
# HELP serve_requests_total completed requests
# TYPE serve_requests_total counter
serve_requests_total 3
# HELP queue_depth requests waiting for admission
# TYPE queue_depth gauge
queue_depth 2
# HELP ttft_seconds time to first token
# TYPE ttft_seconds histogram
ttft_seconds_bucket{le="0.1"} 1
ttft_seconds_bucket{le="1.0"} 2
ttft_seconds_bucket{le="+Inf"} 3
ttft_seconds_sum 1.25
ttft_seconds_count 3
"""


def test_valid_exposition_is_clean():
    assert lint(VALID) == []


def _expect(text, *fragments):
    """Lint must produce >= 1 error, and each fragment must appear."""
    errors = lint(text)
    assert errors, f"expected errors for {fragments}"
    for frag in fragments:
        assert any(frag in e for e in errors), (frag, errors)
    return errors


class TestHelpCoverage:
    def test_missing_help_for_type_declared_family(self):
        text = VALID.replace(
            "# HELP queue_depth requests waiting for admission\n", "")
        _expect(text, "metric 'queue_depth': missing HELP line")

    def test_empty_help_text(self):
        text = VALID.replace(
            "# HELP queue_depth requests waiting for admission",
            "# HELP queue_depth")
        _expect(text, "empty HELP text for 'queue_depth'")

    def test_whitespace_only_help_text(self):
        text = VALID.replace(
            "# HELP queue_depth requests waiting for admission",
            "# HELP queue_depth    ")
        _expect(text, "empty HELP text for 'queue_depth'")

    def test_malformed_help_bad_name(self):
        text = "# HELP 0bad some text\n" + VALID
        _expect(text, "malformed HELP line")

    def test_duplicate_help(self):
        text = VALID + "# HELP queue_depth said twice\n"
        _expect(text, "duplicate HELP for 'queue_depth'")

    def test_help_without_samples_still_counts_as_coverage(self):
        """HELP + TYPE with zero samples is legal exposition (a histogram
        that never observed still emits buckets, but a family awaiting
        traffic may legitimately be declared first)."""
        text = ("# HELP pending_total not yet incremented\n"
                "# TYPE pending_total counter\n")
        assert lint(text) == []


class TestPreexistingClasses:
    """The original failure classes must survive the HELP additions."""

    def test_counter_without_total_suffix(self):
        text = ("# HELP reqs completed requests\n"
                "# TYPE reqs counter\n"
                "reqs 3\n")
        _expect(text, "should end in _total")

    def test_sample_without_type(self):
        _expect(VALID + "orphan_metric 1\n", "has no TYPE line")

    def test_duplicate_type(self):
        text = VALID + ("# TYPE queue_depth gauge\n")
        _expect(text, "duplicate TYPE for 'queue_depth'")

    def test_unparseable_sample(self):
        _expect(VALID + "queue_depth oops extra stuff ~\n",
                "unparseable sample")

    def test_bad_value(self):
        _expect(VALID + "queue_depth notafloat\n", "bad value")

    def test_histogram_missing_inf_bucket(self):
        text = VALID.replace('ttft_seconds_bucket{le="+Inf"} 3\n', "")
        errors = _expect(text, "missing +Inf bucket")
        # _count can no longer be cross-checked, but the class still fires
        assert any("ttft_seconds" in e for e in errors)

    def test_histogram_decreasing_cumulative_counts(self):
        text = VALID.replace('ttft_seconds_bucket{le="1.0"} 2',
                             'ttft_seconds_bucket{le="1.0"} 0')
        _expect(text, "cumulative bucket counts decrease")

    def test_histogram_count_mismatch(self):
        text = VALID.replace("ttft_seconds_count 3", "ttft_seconds_count 7")
        _expect(text, "_count 7.0 != +Inf bucket 3.0")

    def test_histogram_missing_sum(self):
        text = VALID.replace("ttft_seconds_sum 1.25\n", "")
        _expect(text, "missing _sum")

    def test_duplicate_sample(self):
        _expect(VALID + "queue_depth 2\n", "duplicate sample")

    def test_bad_label(self):
        text = VALID + ("# HELP labeled_total labeled counter\n"
                        "# TYPE labeled_total counter\n"
                        'labeled_total{bad label="x"} 1\n')
        _expect(text, "bad label")


def test_cli_red_and_green(tmp_path, capsys):
    from check_prom import main
    good = tmp_path / "good.prom"
    good.write_text(VALID)
    assert main(["check_prom.py", str(good)]) == 0
    bad = tmp_path / "bad.prom"
    bad.write_text(VALID.replace(
        "# HELP queue_depth requests waiting for admission\n", ""))
    assert main(["check_prom.py", str(bad)]) == 1
    err = capsys.readouterr().err
    assert "missing HELP line" in err
