"""Numerics tests for the COALA core (Props 1-4, Algorithms 1-2, Eq. 5)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    balanced_split, coala_factors, coala_project, coala_alpha_factors,
    eym_truncate, r_from_x, rsvd_left_singvecs, weighted_error,
)
from repro.core import baselines, theory
from repro.core.coala import mu_from_lambda


def _rand(m, n, key, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), (m, n), jnp.float32)


class TestProposition1:
    """W' = U_r U_rᵀ W attains the optimal weighted error."""

    @pytest.mark.parametrize("m,n,k,r", [(24, 16, 40, 4), (16, 24, 64, 6),
                                         (32, 32, 8, 3)])  # incl. k < n (limited data)
    def test_attains_optimum(self, m, n, k, r):
        w, x = _rand(m, n, 0), _rand(n, k, 1)
        w_apx = coala_project(w, x, rank=r)
        err = weighted_error(w, w_apx, x)
        opt = theory.optimal_weighted_error(w, x, r)
        np.testing.assert_allclose(err, opt, rtol=1e-4, atol=1e-5)

    def test_rank_constraint(self):
        w, x = _rand(20, 16, 0), _rand(16, 50, 1)
        res = coala_factors(w, x, rank=5)
        assert res.a.shape == (20, 5) and res.b.shape == (5, 16)
        assert np.linalg.matrix_rank(np.asarray(res.w_approx), tol=1e-4) <= 5

    def test_beats_or_matches_baselines(self):
        w, x = _rand(24, 16, 2), _rand(16, 48, 3)
        r = 4
        coala_err = weighted_error(w, coala_project(w, x, rank=r), x)
        for a, b in [baselines.plain_svd(w, r), baselines.asvd(w, x, r)]:
            assert coala_err <= weighted_error(w, a @ b, x) + 1e-5


class TestProposition2:
    """QR preprocessing gives the identical solution."""

    def test_r_path_equals_x_path(self):
        w, x = _rand(20, 12, 4), _rand(12, 300, 5)
        r_factor = r_from_x(x)
        direct = coala_project(w, x, rank=4)
        via_r = coala_project(w, r_factor=r_factor, rank=4)
        # solutions may differ only in the null space when degenerate;
        # here X is full row rank so W' is unique in the row space metric
        np.testing.assert_allclose(np.asarray(direct), np.asarray(via_r),
                                   rtol=1e-4, atol=1e-5)

    def test_chunked_tsqr_matches(self):
        w, x = _rand(20, 12, 6), _rand(12, 1000, 7)
        full = coala_project(w, x, rank=4)
        chunked = coala_project(w, x, rank=4, chunk_tokens=128)
        np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                                   rtol=1e-4, atol=1e-5)


class TestRegularization:
    """Prop. 3 + Eq. (5) + Theorem 1."""

    def test_augmentation_equivalence(self):
        w, x = _rand(16, 10, 8), _rand(10, 6, 9)     # k < n: ill-posed
        mu = 0.3
        via_aug = coala_project(w, x, rank=3, mu=mu)
        x_tilde = jnp.concatenate([x, jnp.sqrt(mu) * jnp.eye(10)], axis=1)
        direct = coala_project(w, x_tilde, rank=3)
        np.testing.assert_allclose(np.asarray(via_aug), np.asarray(direct),
                                   rtol=1e-4, atol=1e-5)

    def test_regularized_objective_optimal(self):
        w, x = _rand(16, 10, 10), _rand(10, 6, 11)
        mu, r = 0.5, 3
        w_mu = coala_project(w, x, rank=r, mu=mu)
        x_tilde = jnp.concatenate([x, jnp.sqrt(mu) * jnp.eye(10)], axis=1)
        err = weighted_error(w, w_mu, x_tilde)
        opt = theory.optimal_weighted_error(w, x_tilde, r)
        np.testing.assert_allclose(err, opt, rtol=1e-4, atol=1e-5)

    def test_thm1_bound_holds_and_linear(self):
        w, x = _rand(16, 12, 12), _rand(12, 8, 13)   # rank-deficient X
        r = 3
        w0 = coala_project(w, x, rank=r, mu=0.0)
        errs = []
        for mu in [1e-3, 1e-4, 1e-5]:
            w_mu = coala_project(w, x, rank=r, mu=mu)
            diff = float(jnp.linalg.norm(w0 - w_mu))
            bound = float(theory.thm1_bound(w, x, r, mu))
            assert diff <= bound * (1 + 1e-3), f"mu={mu}: {diff} > {bound}"
            errs.append(diff)
        # linear convergence: error drops ~10x per decade of mu
        assert errs[1] < errs[0] * 0.5 and errs[2] < errs[1] * 0.5

    def test_eq5_mu_from_lambda(self):
        w, x = _rand(20, 12, 14), _rand(12, 100, 15)
        r_factor = r_from_x(x)
        res = coala_factors(w, x, rank=4, lam=4.0)
        w0 = coala_project(w, x, rank=4)
        expect = 4.0 * float(weighted_error(w, w0, x) ** 2) / \
            float(jnp.sum((w0 - w) ** 2))
        np.testing.assert_allclose(res.mu, expect, rtol=1e-3)
        # and mu_from_lambda agrees when fed R directly
        mu2 = float(mu_from_lambda(w, w0, r_factor, 4.0))
        np.testing.assert_allclose(mu2, expect, rtol=1e-3)


class TestProposition4:
    def test_alpha0_is_pissa(self):
        """α=0: plain EYM subspace of W."""
        w, x = _rand(18, 12, 16), _rand(12, 40, 17)
        a, b = coala_alpha_factors(w, x, rank=4, alpha=0.0)
        np.testing.assert_allclose(np.asarray(a @ b), np.asarray(eym_truncate(w, 4)),
                                   rtol=1e-4, atol=1e-5)

    def test_alpha1_equals_algorithm1(self):
        w, x = _rand(18, 12, 18), _rand(12, 40, 19)
        a, b = coala_alpha_factors(w, x, rank=4, alpha=1.0)
        np.testing.assert_allclose(np.asarray(a @ b),
                                   np.asarray(coala_project(w, x, rank=4)),
                                   rtol=1e-4, atol=1e-5)

    def test_alpha2_matches_corda_objective(self):
        """α=2 solves min ||(W−W')XXᵀ||_F; compare against CorDA on a
        well-conditioned X where the fragile path still works."""
        w = _rand(18, 12, 20)
        x = _rand(12, 200, 21) + 0.1 * jnp.eye(12, 200)
        a, b = coala_alpha_factors(w, x, rank=4, alpha=2.0)
        ac, bc = baselines.corda(w, x, rank=4)
        gram = x @ x.T
        err_ours = jnp.linalg.norm((w - a @ b) @ gram)
        err_corda = jnp.linalg.norm((w - ac @ bc) @ gram)
        np.testing.assert_allclose(float(err_ours), float(err_corda),
                                   rtol=1e-3)

    def test_alpha1_with_mu_equals_algorithm2(self):
        """Regression: the α=1 fast path used to swallow any μ >= 0 — a
        regularized request silently returned the unregularized solution.
        With μ > 0 the α-path must match Algorithm 2 and differ from μ=0."""
        w, x = _rand(16, 10, 40), _rand(10, 6, 41)       # k < n: ill-posed
        mu = 0.5
        a, b = coala_alpha_factors(w, x, rank=3, alpha=1.0, mu=mu)
        res = coala_factors(w, x, rank=3, mu=mu)
        np.testing.assert_allclose(np.asarray(a @ b), np.asarray(res.w_approx),
                                   rtol=1e-4, atol=1e-5)
        w0 = coala_project(w, x, rank=3)                 # μ = 0 solution
        assert float(jnp.linalg.norm(a @ b - w0)) > 1e-3

    def test_alpha2_with_mu_matches_direct_reference(self):
        """μ-regularized α-family against a direct fp64 eigendecomposition
        of W((XXᵀ)^α + μI)Wᵀ."""
        w, x = _rand(18, 12, 42), _rand(12, 40, 43)
        mu, r = 0.7, 4
        a, b = coala_alpha_factors(w, x, rank=r, alpha=2.0, mu=mu)
        w64, x64 = np.asarray(w, np.float64), np.asarray(x, np.float64)
        gram = x64 @ x64.T
        weight = gram @ gram + mu * np.eye(12)           # (XXᵀ)² + μI
        evals, evecs = np.linalg.eigh(w64 @ weight @ w64.T)
        u_r = evecs[:, np.argsort(evals)[::-1][:r]]
        ref = u_r @ u_r.T @ w64
        np.testing.assert_allclose(np.asarray(a @ b), ref, rtol=1e-3,
                                   atol=1e-4)

    def test_negative_mu_raises(self):
        w, x = _rand(18, 12, 44), _rand(12, 40, 45)
        with pytest.raises(ValueError, match="non-negative"):
            coala_alpha_factors(w, x, rank=4, alpha=1.0, mu=-0.5)


class TestBalancedSplit:
    def test_geometric_mean_for_arbitrary_factors(self):
        """Regression: the old scale sqrt(||B row||) assumed orthonormal A
        columns; for arbitrary factors it left ||A col|| and ||B row||
        unequal. The fix must equalize both at the geometric mean while
        preserving the product, for badly scaled A."""
        a = np.asarray(_rand(20, 5, 46)) * \
            np.array([1e-3, 1e-2, 1.0, 1e2, 1e3])[None, :]
        b = np.asarray(_rand(5, 14, 47))
        a2, b2 = balanced_split(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_allclose(np.asarray(a2 @ b2), a @ b,
                                   rtol=1e-4, atol=1e-5)
        an = np.linalg.norm(np.asarray(a2), axis=0)
        bn = np.linalg.norm(np.asarray(b2), axis=1)
        np.testing.assert_allclose(an, bn, rtol=1e-4)
        geo = np.sqrt(np.linalg.norm(a, axis=0) * np.linalg.norm(b, axis=1))
        np.testing.assert_allclose(an, geo, rtol=1e-4)

    def test_orthonormal_a_keeps_old_behavior(self):
        """With orthonormal A columns (the COALA U_r case) the geometric
        mean reduces to the old sqrt(||B row||) scaling."""
        a = jnp.linalg.qr(_rand(20, 5, 48))[0]
        b = _rand(5, 14, 49)
        a2, b2 = balanced_split(a, b)
        expect = np.sqrt(np.linalg.norm(np.asarray(b), axis=1))
        np.testing.assert_allclose(np.linalg.norm(np.asarray(a2), axis=0),
                                   expect, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(a2 @ b2), np.asarray(a @ b),
                                   rtol=1e-4, atol=1e-5)


class TestRSVD:
    def test_rsvd_subspace_accuracy(self):
        # decaying spectrum (realistic activation statistics -> clear gap)
        u = jnp.linalg.qr(_rand(64, 48, 22))[0]
        v = jnp.linalg.qr(_rand(48, 48, 23))[0]
        s = jnp.logspace(0, -3, 48).astype(jnp.float32)
        m = (u * s[None, :]) @ v.T
        u_exact = jnp.linalg.svd(m, full_matrices=False)[0][:, :8]
        u_rand = rsvd_left_singvecs(m, 8, oversample=8, power_iters=3)
        d = float(theory.projector_distance(u_exact, u_rand))
        assert d < 5e-2, d

    def test_rsvd_coala_error_close_to_exact(self):
        w, x = _rand(64, 48, 24), _rand(48, 256, 25)
        exact = weighted_error(w, coala_project(w, x, rank=8), x)
        rnd = weighted_error(
            w, coala_project(w, x, rank=8, use_rsvd=True, rsvd_power_iters=3), x)
        assert float(rnd) <= float(exact) * 1.05


class TestStability:
    """The paper's Fig. 1 / Example G.1: Gram-based methods lose √ε accuracy."""

    def _ill_conditioned(self, n=32, k=64, cond=1e7, key=30):
        u = jnp.linalg.qr(_rand(n, n, key))[0]
        v = jnp.linalg.qr(_rand(k, n, key + 1))[0]
        s = jnp.logspace(0, -np.log10(cond), n).astype(jnp.float32)
        return (u * s[None, :]) @ v.T                    # X: (n, k)

    def test_qr_path_beats_gram_paths_when_ill_conditioned(self):
        # cond pinned at 1e9: Gram conditioning is cond^2 = 1e18 >> 1/eps32,
        # so the Gram path degrades on every BLAS (at the seed default of
        # 1e7 some BLAS kept it accurate and the 10x margin never opened);
        # measured margin at this seed is ~29x
        w = _rand(24, 32, 31)
        x = self._ill_conditioned(cond=1e9)
        r = 6
        # fp64 ground truth via numpy
        w64, x64 = np.asarray(w, np.float64), np.asarray(x, np.float64)
        m = w64 @ x64
        u = np.linalg.svd(m)[0][:, :r]
        w_ref = u @ u.T @ w64

        def rel(w_apx):
            return np.linalg.norm(np.asarray(w_apx, np.float64) - w_ref, 2) / \
                np.linalg.norm(w_ref, 2)

        coala_err = rel(coala_project(w, x, rank=r))
        gram = x @ x.T
        a, b = baselines.svd_llm_v2(w, gram, r)
        v2_err = rel(a @ b)
        assert coala_err < 1e-2, coala_err
        # Gram-based path degrades by orders of magnitude (or NaNs)
        assert not np.isfinite(v2_err) or v2_err > 10 * coala_err

    def test_cholesky_fails_on_singular_gram(self):
        """Rank-deficient X: SVD-LLM's Cholesky produces non-finite factors,
        COALA stays finite and optimal."""
        w = _rand(16, 24, 32)
        x_thin = _rand(24, 8, 33)                        # rank 8 < n=24
        gram = x_thin @ x_thin.T
        a, b = baselines.svd_llm(w, gram, 4)
        assert not np.all(np.isfinite(np.asarray(a @ b)))
        w_apx = coala_project(w, x_thin, rank=4)
        assert np.all(np.isfinite(np.asarray(w_apx)))
