"""Paged-attention decode path + shape buckets: parity and recompile guards.

Three-way token parity (greedy): the paged-kernel read path must match the
gather-into-contiguous path and the legacy fixed-batch ``ServeEngine``
oracle, across staggered mixed-length traces, preemption, GQA configs with
sliding window + logit softcap (gemma2), and with the actual Pallas kernel
executing in interpret mode. Plus: a request joining exactly at a bucket
edge, and the compile-cache counter staying ≤ the shape-bucket count.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import ContinuousEngine, ServeEngine
from repro.serve.engine import default_bucket_sizes


@pytest.fixture(scope="module")
def smollm():
    cfg = get_smoke_config("smollm_135m")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def gemma2():
    """GQA with local sliding-window layers and attn logit softcap."""
    cfg = get_smoke_config("gemma2_27b")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _cont(model, params, **kw):
    kw.setdefault("block_size", 4)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_running", 4)
    return ContinuousEngine(model, params, compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32, **kw)


def _oracle_tokens(model, params, prompt, n):
    leg = ServeEngine(model, params, compute_dtype=jnp.float32,
                      cache_dtype=jnp.float32)
    return np.asarray(leg.generate(jnp.asarray(prompt)[None],
                                   max_new_tokens=n))[0, len(prompt):]


def _staggered(eng, prompts, news):
    ids = []
    for p, n in zip(prompts, news):
        ids.append(eng.submit(p, n))
        eng.step()                          # join mid-decode
    eng.run()
    fin = {r.req_id: r for r in eng.finished}
    return [np.asarray(fin[i].out_tokens) for i in ids]


class TestPagedParity:
    def test_paged_vs_gather_vs_oracle_short_trace(self, smollm):
        """Fast tier: one staggered mixed-length case per read path."""
        cfg, model, params = smollm
        rng = np.random.RandomState(0)
        lens, news = [3, 9], [5, 3]
        prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
                   for l in lens]
        paged = _staggered(_cont(model, params, paged_kernel=True),
                           prompts, news)
        gathered = _staggered(_cont(model, params, paged_kernel=False),
                              prompts, news)
        for p, n, a, b in zip(prompts, news, paged, gathered):
            ref = _oracle_tokens(model, params, p, n)
            np.testing.assert_array_equal(ref, a, err_msg="paged != oracle")
            np.testing.assert_array_equal(ref, b, err_msg="gather != oracle")

    @pytest.mark.slow
    def test_paged_vs_gather_vs_oracle_mixed_trace(self, smollm):
        cfg, model, params = smollm
        rng = np.random.RandomState(0)
        lens, news = [3, 9, 5, 12], [5, 3, 7, 2]
        prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
                   for l in lens]
        paged = _staggered(_cont(model, params, paged_kernel=True),
                           prompts, news)
        gathered = _staggered(_cont(model, params, paged_kernel=False),
                              prompts, news)
        for p, n, a, b in zip(prompts, news, paged, gathered):
            ref = _oracle_tokens(model, params, p, n)
            np.testing.assert_array_equal(ref, a, err_msg="paged != oracle")
            np.testing.assert_array_equal(ref, b, err_msg="gather != oracle")

    def test_paged_interpret_kernel_in_engine(self, smollm):
        """The real Pallas kernel (interpret mode) drives a whole serve."""
        cfg, model, params = smollm
        rng = np.random.RandomState(3)
        prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
                   for l in (4, 7)]
        eng = _cont(model, params, paged_kernel=True,
                    paged_attn_impl="pallas")
        out = _staggered(eng, prompts, [3, 3])
        for p, got in zip(prompts, out):
            np.testing.assert_array_equal(
                _oracle_tokens(model, params, p, 3), got)

    def test_paged_preemption_parity(self, smollm):
        """Pool pressure forces preemption; the paged path must resume every
        request on the same greedy trajectory."""
        cfg, model, params = smollm
        rng = np.random.RandomState(1)
        prompts = [rng.randint(0, cfg.vocab_size, (4,)).astype(np.int32)
                   for _ in range(3)]
        eng = _cont(model, params, paged_kernel=True, block_size=2,
                    num_blocks=9, max_running=3)
        ids = [eng.submit(p, 6) for p in prompts]
        fin = {r.req_id: r for r in eng.run()}
        assert sum(r.preemptions for r in fin.values()) > 0
        for p, rid in zip(prompts, ids):
            np.testing.assert_array_equal(
                _oracle_tokens(model, params, p, 6),
                np.asarray(fin[rid].out_tokens))

    @pytest.mark.slow
    def test_paged_gqa_window_softcap(self, gemma2):
        """gemma2: grouped KV heads, alternating local sliding-window layers,
        logit softcap — long enough that the window actually truncates."""
        cfg, model, params = gemma2
        assert cfg.local_window > 0 and cfg.attn_logit_softcap > 0
        rng = np.random.RandomState(2)
        prompt = rng.randint(0, cfg.vocab_size, (30,)).astype(np.int32)
        n = cfg.local_window + 8 - 30          # decode well past the window
        ref = _oracle_tokens(model, params, prompt, n)
        for paged in (True, False):
            eng = _cont(model, params, paged_kernel=paged, num_blocks=96)
            rid = eng.submit(prompt, n)
            fin = {r.req_id: r for r in eng.run()}
            np.testing.assert_array_equal(
                ref, np.asarray(fin[rid].out_tokens),
                err_msg=f"paged_kernel={paged} diverged")

    def test_paged_rejected_for_mla(self):
        """MLA keeps latent caches the paged kernel can't read: auto-detect
        must fall back to gather, and forcing the kernel must fail loudly."""
        cfg = get_smoke_config("deepseek_v2_lite_16b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = _cont(model, params)
        assert not eng.paged_kernel
        with pytest.raises(ValueError, match="unsupported"):
            _cont(model, params, paged_kernel=True)


class TestShapeBuckets:
    def test_default_buckets_cover_max_running(self):
        assert default_bucket_sizes(8) == (1, 2, 4, 8)
        assert default_bucket_sizes(6) == (1, 2, 4, 6)
        assert default_bucket_sizes(1) == (1,)

    def test_join_exactly_at_bucket_edge(self, smollm):
        """Third request arrives exactly when the batch crosses the 2->4
        bucket edge; tokens must stay on the oracle trajectory and every
        decode signature must come from the bucket set."""
        cfg, model, params = smollm
        rng = np.random.RandomState(4)
        prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
                   for l in (5, 5, 6)]
        eng = _cont(model, params, bucket_sizes=(1, 2, 4))
        ids = [eng.submit(prompts[0], 6), eng.submit(prompts[1], 6)]
        eng.step()                              # both running: batch bucket 2
        assert {s[0] for s in eng._decode_shapes} == {2}
        ids.append(eng.submit(prompts[2], 4))   # joins: 3 -> pads to bucket 4
        eng.run()
        assert {s[0] for s in eng._decode_shapes} <= {2, 4}
        fin = {r.req_id: r for r in eng.finished}
        for p, n, rid in zip(prompts, (6, 6, 4), ids):
            np.testing.assert_array_equal(
                _oracle_tokens(model, params, p, n),
                np.asarray(fin[rid].out_tokens))

    @pytest.mark.parametrize(
        "paged", [True, pytest.param(False, marks=pytest.mark.slow)])
    def test_recompile_guard_staggered_trace(self, smollm, paged):
        """Regression guard: a mixed-length staggered trace (the envelope
        both grows and shrinks) must trigger at most
        len(batch buckets) x len(block buckets) decode compilations."""
        cfg, model, params = smollm
        rng = np.random.RandomState(5)
        lens = [3, 11, 6, 14, 4, 9]
        news = [6, 4, 8, 3, 7, 5]
        prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
                   for l in lens]
        eng = _cont(model, params, paged_kernel=paged)
        for (p, n) in zip(prompts, news):
            eng.submit(p, n)
            eng.step()
        eng.run()
        m = eng.metrics()
        # every request < 32 tokens -> <= 8 blocks -> pow2 buckets {1,2,4,8}
        n_block_buckets = 4
        n_shape_buckets = len(eng.bucket_sizes) * n_block_buckets
        assert m["decode_steps"] >= 10
        assert m["decode_compiles"] <= n_shape_buckets, m
        assert m["decode_compiles"] <= m["decode_steps"] // 2, \
            "bucketing should compile far less often than it steps"
        assert m["decode_shapes"] <= n_shape_buckets
