"""AOT warmup, async detokenize pipeline, offline lane.

The zero-stall contract: ``warmup(max_len)`` enumerates the *complete*
closed set of jit signatures admissible traffic can hit and executes each
once against the trash page — so after warmup the compile counters must
stay exactly frozen (``== 0`` new compiles, not ``<= bucket count``) under
staggered mixed-length traffic including prefix-cache hits at nonzero
offsets, and the first request's TTFT is steady-state (orders of magnitude
under a cold engine's compile-dominated first TTFT). The async host
pipeline must be invisible to results: token-exact greedy parity with the
inline synchronous oracle, identical detokenized text, and per-request
callback events in exact emission order. The offline lane reorders
admission (length-sorted packing) but per-request greedy trajectories are
deterministic, so tokens must match the online engine request-for-request.

Configs are tiny (block 4, pool 24, 2 running slots, one explicit prefill
bucket) so each warmup compiles ~16 signatures, not a production grid.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import ContinuousEngine

MAX_LEN = 16    # worst-case per-request cache positions in every trace here


@pytest.fixture(scope="module")
def smollm():
    cfg = get_smoke_config("smollm_135m")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _engine(model, params, **kw):
    kw.setdefault("block_size", 4)
    kw.setdefault("num_blocks", 24)
    kw.setdefault("max_running", 2)
    kw.setdefault("prefill_bucket_sizes", (8,))
    return ContinuousEngine(model, params, compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32, **kw)


def _trace(cfg, seed=0):
    """Mixed-length requests, two sharing a block-aligned 4-token prefix so
    the steady stream includes a prefix-cache hit (prefill at offset > 0).
    Every (prompt + new) stays within MAX_LEN."""
    rng = np.random.RandomState(seed)
    common = rng.randint(1, cfg.vocab_size, (4,)).astype(np.int32)
    mk = lambda n: rng.randint(1, cfg.vocab_size, (n,)).astype(np.int32)
    return [
        (np.concatenate([common, mk(4)]), 6),
        (mk(3), 5),
        (mk(10), 4),
        (np.concatenate([common, mk(7)]), 5),
        (mk(2), 6),
    ]


def _serve_staggered(eng, reqs, **submit_kw):
    """Submit one request per engine step so joiners land mid-decode."""
    ids = []
    for prompt, nn in reqs:
        ids.append(eng.submit(prompt, nn, **submit_kw))
        eng.step()
    eng.run()
    fin = {r.req_id: r for r in eng.finished}
    return [fin[i] for i in ids]


def test_zero_compiles_after_warmup(smollm):
    cfg, model, params = smollm
    eng = _engine(model, params)
    eng.warmup(max_len=MAX_LEN)
    base_decode = eng.decode_compile_count()
    base_prefill = eng.prefill_compile_count()
    _serve_staggered(eng, _trace(cfg))
    # the invariant: exactly zero — not "at most the bucket count"
    assert eng.post_warmup_compiles() == 0
    assert eng.decode_compile_count() == base_decode
    assert eng.prefill_compile_count() == base_prefill
    assert eng.metrics()["post_warmup_compiles"] == 0
    assert eng.metrics()["warmup_seconds"] > 0.0
    # the prefix-hit path (offset > 0 prefill signatures) actually ran
    assert eng.metrics()["prefix_hit_tokens"] > 0
    # warming again is a no-op: every signature is already cached
    again = eng.warmup(max_len=MAX_LEN)
    assert eng.decode_compile_count() == base_decode
    assert eng.prefill_compile_count() == base_prefill
    assert again["warmup_seconds"] < 1.0


def test_warm_first_ttft_is_steady_state(smollm):
    cfg, model, params = smollm
    reqs = _trace(cfg, seed=3)
    cold = _engine(model, params)
    cold_first = _serve_staggered(cold, reqs)[0].ttft
    warm = _engine(model, params)
    warm.warmup(max_len=MAX_LEN)
    warm_first = _serve_staggered(warm, reqs)[0].ttft
    # a cold first request pays >= 1 XLA compile (seconds on this CPU); a
    # warmed one pays only the steady-state prefill+decode, so even a very
    # generous bound separates them without wall-clock flakiness
    assert warm_first < cold_first / 2
    assert warm.metrics()["post_warmup_compiles"] == 0


def test_async_detok_parity_and_callback_order(smollm):
    cfg, model, params = smollm
    reqs = _trace(cfg, seed=5)
    detok = lambda t: f"<{t}>"          # noqa: E731

    def serve(async_on):
        events = []
        eng = _engine(model, params, detokenizer=detok, async_detok=async_on)
        fins = _serve_staggered(eng, reqs, stream_callback=events.append)
        eng.flush_stream()
        return fins, events

    sync_fins, sync_events = serve(False)
    async_fins, async_events = serve(True)
    for s, a in zip(sync_fins, async_fins):
        assert s.out_tokens == a.out_tokens          # token-exact greedy
        assert s.text == a.text == "".join(f"<{t}>" for t in s.out_tokens)
    # per-request event streams are identical and in emission order
    for fins, events in ((sync_fins, sync_events), (async_fins, async_events)):
        for r in fins:
            evs = [e for e in events if e.req_id == r.req_id]
            assert [e.token for e in evs] == r.out_tokens
            assert [e.index for e in evs] == list(range(len(evs)))
            assert [e.done for e in evs] == \
                [False] * (len(evs) - 1) + [True]
            assert [e.text for e in evs] == \
                [f"<{t}>" for t in r.out_tokens]
    key = lambda e: (e.req_id, e.index, e.token, e.text, e.done)  # noqa: E731
    assert sorted(map(key, sync_events)) == sorted(map(key, async_events))


def test_offline_lane_parity(smollm):
    cfg, model, params = smollm
    reqs = _trace(cfg, seed=7)
    online = _engine(model, params)
    ids = [online.submit(p, n) for p, n in reqs]
    fin = {r.req_id: r for r in online.run()}
    offline = _engine(model, params)
    results = offline.run_offline(reqs)
    assert len(results) == len(reqs)
    for (prompt, _), rid, res in zip(reqs, ids, results):
        np.testing.assert_array_equal(res.prompt, prompt)  # input order kept
        assert res.out_tokens == fin[rid].out_tokens       # token parity
    # length-sorted packing really batched prefills: fewer batched calls
    # than requests (same-bucket prompts admitted together)
    m = offline.metrics()
    assert m["requests"] == len(reqs)
    assert m["prefill_batches"] < len(reqs)
