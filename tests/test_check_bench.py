"""Perf-regression gate (tools/check_bench.py): the CI step must go red.

Drives the gate the way CI does — artifact JSON vs a committed baseline —
and proves each failure class actually fails: a seeded throughput
regression outside the band, a violated hard invariant (which a baseline
refresh must NOT be able to relax), rows dropped from or added to the
schema, NaN/null values, and a benchmarks.run suite-error map. Plus the
green path: a fresh artifact validated against its own ``--update``
baseline passes, and small in-band drift passes.
"""
import copy
import json
import os
import pathlib
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "tools"))
import check_bench  # noqa: E402


def _artifact():
    """Minimal but schema-realistic benchmarks.run serve+dist artifact."""
    rows = [
        ("serve/paged_tok_per_s", "120.50"),
        ("serve/gather_decode_tok_per_s", "80.00"),
        ("serve/paged_vs_gather_decode_speedup", "1.450"),
        ("serve/warm_ttft_ms", "35.1"),
        ("serve/cold_ttft_ms", "2400.0"),
        ("serve/warmup_seconds", "12.31"),
        ("serve/post_warmup_compiles", 0),
        ("serve/offline_tok_per_s", "95.30"),
        ("serve/obs_overhead_pct", "1.25"),
        ("serve/slo_goodput", "1.0"),
        ("serve/serve_tpot_seconds_p50", "0.012"),
        ("serve/serve_tpot_seconds_p99", "0.019"),
        ("serve/serve_request_e2e_seconds_p50", "0.23"),
        ("serve/serve_request_e2e_seconds_p99", "0.41"),
        ("serve/spec_accept_rate", "0.912"),
        ("serve/spec_decode_speedup", "1.140"),
        ("serve/spec_greedy_parity", "1.0"),
        ("serve/spec_post_warmup_compiles", 0),
        ("serve/recalib_greedy_parity", "1.0"),
        ("serve/recalib_swaps", 1),
        ("serve/recalib_post_warmup_compiles", 0),
        ("serve/recalib_swap_ms", "45.2"),
        ("serve/recalib_tokens_to_clearance", 81),
        ("serve/recalib_r_gram_rel_err", "5.4e-07"),
        ("dist/calib_sharded8_tok_per_s", "5400.0"),
        ("dist/r_gram_rel_err", "3.1e-07"),
    ]
    return {"benchmarks": ["serve", "dist"], "smoke": True, "errors": {},
            "rows": [{"name": n, "value": v, "notes": ""} for n, v in rows]}


@pytest.fixture()
def gate(tmp_path):
    """(artifact dict, writer, checker) against a tmp baseline dir."""
    art_path = tmp_path / "BENCH_serve.json"
    base_path = tmp_path / "baselines" / "BENCH_serve.json"

    def write(artifact):
        art_path.write_text(json.dumps(artifact))
        return art_path

    def check(artifact):
        return check_bench.check_artifact(write(artifact), base_path)

    write(_artifact())
    assert check_bench.update_baseline(art_path, base_path) == []
    return _artifact(), check, base_path


def test_fresh_artifact_passes_its_baseline(gate):
    art, check, _ = gate
    assert check(art) == []


def test_in_band_drift_passes(gate):
    art, check, _ = gate
    art["rows"][0]["value"] = "100.00"          # -17% of 120.5: inside ±40%
    assert check(art) == []


def test_seeded_throughput_regression_fails(gate):
    art, check, _ = gate
    art["rows"][0]["value"] = "60.00"           # -50%: outside the band
    errs = check(art)
    assert any("serve/paged_tok_per_s" in e and "outside" in e for e in errs)


def test_band_override_tightens(gate):
    art, check, base_path = gate
    doc = json.loads(base_path.read_text())
    doc["rows"]["serve/paged_tok_per_s"]["band_pct"] = 5
    base_path.write_text(json.dumps(doc))
    art["rows"][0]["value"] = "100.00"          # -17%: fine at 40, not at 5
    errs = check(art)
    assert any("serve/paged_tok_per_s" in e for e in errs)


@pytest.mark.parametrize("name,value,frag", [
    ("serve/post_warmup_compiles", 3, "hard invariant"),
    ("serve/obs_overhead_pct", "7.5", "hard invariant"),
    ("serve/slo_goodput", "0.75", "hard invariant"),
    ("serve/paged_vs_gather_decode_speedup", "0.90", "hard invariant"),
    ("serve/spec_decode_speedup", "0.95", "hard invariant"),
    ("serve/spec_greedy_parity", "0.0", "hard invariant"),
    ("serve/spec_accept_rate", "0.0", "hard invariant"),
    ("serve/spec_post_warmup_compiles", 2, "hard invariant"),
    ("serve/recalib_swaps", 0, "hard invariant"),
    ("serve/recalib_post_warmup_compiles", 1, "hard invariant"),
    ("serve/recalib_greedy_parity", "0.0", "hard invariant"),
    ("serve/recalib_r_gram_rel_err", "1e-2", "hard invariant"),
    ("dist/r_gram_rel_err", "2e-3", "hard invariant"),
])
def test_hard_invariant_violations_fail(gate, name, value, frag):
    art, check, _ = gate
    row = next(r for r in art["rows"] if r["name"] == name)
    row["value"] = value
    errs = check(art)
    assert any(name in e and frag in e for e in errs)


def test_baseline_refresh_cannot_relax_hard_invariants(tmp_path):
    """--update on a regressed artifact rewrites the bands, but the hard
    invariants live in the tool: validation still fails."""
    art = _artifact()
    next(r for r in art["rows"]
         if r["name"] == "serve/post_warmup_compiles")["value"] = 2
    path = tmp_path / "BENCH_serve.json"
    path.write_text(json.dumps(art))
    base = tmp_path / "baselines" / "BENCH_serve.json"
    assert check_bench.update_baseline(path, base) == []
    errs = check_bench.check_artifact(path, base)
    assert any("hard invariant" in e
               and "serve/post_warmup_compiles" in e for e in errs)


def test_dropped_and_unbaselined_rows_fail(gate):
    art, check, _ = gate
    dropped = copy.deepcopy(art)
    dropped["rows"] = [r for r in dropped["rows"]
                       if r["name"] != "serve/offline_tok_per_s"]
    assert any("missing from artifact" in e for e in check(dropped))
    added = copy.deepcopy(art)
    added["rows"].append({"name": "serve/new_metric", "value": "1"})
    assert any("not in baseline" in e for e in check(added))


def test_nan_null_and_suite_errors_fail(gate):
    art, check, _ = gate
    nan = copy.deepcopy(art)
    nan["rows"][3]["value"] = "nan"
    assert any("non-finite" in e for e in check(nan))
    null = copy.deepcopy(art)
    null["rows"][4]["value"] = None
    assert any("null value" in e for e in check(null))
    failed = copy.deepcopy(art)
    failed["errors"] = {"serve": "RuntimeError: boom"}
    assert any("failed in benchmarks.run" in e for e in check(failed))
    # and --update refuses to baseline a failed run
    art_path = gate[2].parent.parent / "BENCH_serve.json"
    art_path.write_text(json.dumps(failed))
    assert any("refusing" in e
               for e in check_bench.update_baseline(art_path, gate[2]))


def test_missing_baseline_is_an_error(tmp_path):
    path = tmp_path / "BENCH_serve.json"
    path.write_text(json.dumps(_artifact()))
    errs = check_bench.check_artifact(path, tmp_path / "nope.json")
    assert any("no committed baseline" in e for e in errs)


def test_update_defaults_band_for_throughput_only(gate):
    _, _, base_path = gate
    rows = json.loads(base_path.read_text())["rows"]
    assert rows["serve/paged_tok_per_s"]["kind"] == "band"
    assert rows["dist/calib_sharded8_tok_per_s"]["kind"] == "band"
    assert rows["serve/warm_ttft_ms"]["kind"] == "present"
    assert rows["serve/post_warmup_compiles"]["kind"] == "present"


def test_cli_exit_codes(tmp_path, capsys, monkeypatch):
    """The CI entrypoint: exit 0 green, exit 1 on a seeded regression."""
    art_path = tmp_path / "BENCH_serve.json"
    art_path.write_text(json.dumps(_artifact()))
    bdir = tmp_path / "baselines"
    argv = ["check_bench.py", str(art_path), "--baseline-dir", str(bdir)]
    monkeypatch.setattr(sys, "argv", argv + ["--update"])
    assert check_bench.main() == 0
    monkeypatch.setattr(sys, "argv", argv)
    assert check_bench.main() == 0
    bad = _artifact()
    bad["rows"][0]["value"] = "10.0"
    art_path.write_text(json.dumps(bad))
    assert check_bench.main() == 1
    out = capsys.readouterr().out
    assert "outside" in out
