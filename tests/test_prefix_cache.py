"""Prefix caching over the paged KV cache: parity + recompile guards.

The cached-prefix path must stay token-identical to both oracles (the
gather-into-contiguous read path and the legacy fixed-batch ``ServeEngine``)
under shared-prefix traffic: full-block hits, mid-block divergence,
copy-on-write forks, LRU eviction under pool pressure, and preemption of a
request whose blocks are shared. Plus: length-bucketed batched suffix
prefill must keep ``prefill_compiles`` at the number of length buckets, not
one compile per prompt length.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import BlockPool, ContinuousEngine, ServeEngine


@pytest.fixture(scope="module")
def smollm():
    cfg = get_smoke_config("smollm_135m")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _cont(model, params, **kw):
    kw.setdefault("block_size", 4)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_running", 4)
    return ContinuousEngine(model, params, compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32, **kw)


def _oracle_tokens(model, params, prompt, n):
    leg = ServeEngine(model, params, compute_dtype=jnp.float32,
                      cache_dtype=jnp.float32)
    return np.asarray(leg.generate(jnp.asarray(prompt)[None],
                                   max_new_tokens=n))[0, len(prompt):]


def _staggered(eng, prompts, news):
    ids = []
    for p, n in zip(prompts, news):
        ids.append(eng.submit(p, n))
        eng.step()                          # join mid-decode
    eng.run()
    fin = {r.req_id: r for r in eng.finished}
    return [np.asarray(fin[i].out_tokens) for i in ids]


def _shared_prefix_prompts(cfg, rng, *, prefix_len, tails):
    shared = rng.randint(0, cfg.vocab_size, (prefix_len,)).astype(np.int32)
    return [np.concatenate(
        [shared, rng.randint(0, cfg.vocab_size, (t,)).astype(np.int32)])
        for t in tails]


class TestPrefixParity:
    @pytest.mark.parametrize("paged", [True, False])
    def test_shared_prefix_full_block_hits(self, smollm, paged):
        """System-prompt traffic: every request after the first reuses the
        shared blocks, and all of them stay on the oracle trajectory on both
        decode read paths."""
        cfg, model, params = smollm
        rng = np.random.RandomState(0)
        prompts = _shared_prefix_prompts(cfg, rng, prefix_len=12,
                                         tails=(3, 5, 7))
        prompts.append(rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32))
        news = [5, 5, 4, 5]
        eng = _cont(model, params, paged_kernel=paged)
        assert eng.prefix_cache
        out = _staggered(eng, prompts, news)
        for p, n, got in zip(prompts, news, out):
            np.testing.assert_array_equal(
                _oracle_tokens(model, params, p, n), got,
                err_msg=f"paged_kernel={paged} diverged under prefix hits")
        m = eng.metrics()
        # 12-token shared prefix = 3 full blocks, reused by requests 2 and 3
        assert m["prefix_hit_tokens"] >= 2 * 12
        assert m["prefix_hit_rate"] > 0.3

    def test_mid_block_divergence_hits_only_full_blocks(self, smollm):
        """A prompt diverging mid-block must reuse exactly the full blocks
        below the divergence point — never a partial match."""
        cfg, model, params = smollm
        rng = np.random.RandomState(1)
        a = rng.randint(0, cfg.vocab_size, (14,)).astype(np.int32)
        b = np.concatenate(
            [a[:10], rng.randint(0, cfg.vocab_size, (4,)).astype(np.int32)])
        assert not np.array_equal(a[:12], b[:12])
        eng = _cont(model, params)          # block_size 4
        out = _staggered(eng, [a, b], [5, 5])
        # b matches a's blocks 0-1 (tokens 0-7); block 2 diverges at pos 10
        assert eng.metrics()["prefix_hit_tokens"] == 8
        for p, got in zip((a, b), out):
            np.testing.assert_array_equal(
                _oracle_tokens(model, params, p, 5), got)

    def test_cow_fork_midblock(self, smollm):
        """Forking a request mid-block shares its table copy-on-write: the
        first divergent write copies just the tail block, and neither the
        parent nor a greedy clone leaves the oracle trajectory."""
        cfg, model, params = smollm
        rng = np.random.RandomState(2)
        p = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
        eng = _cont(model, params)
        rid = eng.submit(p, 8)
        eng.step()                 # prefill + 1 decode -> cache_len 7, mid-block
        sid = eng.fork(rid, seed=99, temperature=1.5)   # diverges
        gid = eng.fork(rid)                             # greedy clone
        eng.run()
        fin = {r.req_id: r for r in eng.finished}
        ref = _oracle_tokens(model, params, p, 8)
        np.testing.assert_array_equal(ref, np.asarray(fin[rid].out_tokens),
                                      err_msg="fork corrupted the parent")
        np.testing.assert_array_equal(ref, np.asarray(fin[gid].out_tokens),
                                      err_msg="greedy fork diverged")
        assert len(fin[sid].out_tokens) == 8
        # both forks shared the parent's partial tail block -> 2 COW copies
        assert eng.pool.stats["cow_copies"] >= 2

    def test_eviction_under_pool_pressure(self, smollm):
        """A pool too small to cache every finished request must LRU-evict
        cached blocks to serve new traffic — without corrupting anything."""
        cfg, model, params = smollm
        rng = np.random.RandomState(3)
        prompts = [rng.randint(0, cfg.vocab_size, (8,)).astype(np.int32)
                   for _ in range(5)]
        eng = _cont(model, params, num_blocks=14, max_running=2)
        ids = [eng.submit(q, 6) for q in prompts]
        fin = {r.req_id: r for r in eng.run()}
        assert eng.pool.stats["evictions"] > 0
        for q, rid in zip(prompts, ids):
            np.testing.assert_array_equal(
                _oracle_tokens(model, params, q, 6),
                np.asarray(fin[rid].out_tokens))
        # resubmit the oldest prompt: parity must survive whatever mix of
        # evicted/cached blocks its lookup now finds
        rid = eng.submit(prompts[0], 6)
        fin = {r.req_id: r for r in eng.run()}
        np.testing.assert_array_equal(
            _oracle_tokens(model, params, prompts[0], 6),
            np.asarray(fin[rid].out_tokens))

    def test_preemption_of_prefix_sharing_request(self, smollm):
        """Pool pressure preempts a request whose blocks are shared with
        other running requests; the survivors keep decoding on the shared
        blocks and the victim resumes on the same trajectory (with prefix
        hits from its own first pass)."""
        cfg, model, params = smollm
        rng = np.random.RandomState(4)
        prompts = _shared_prefix_prompts(cfg, rng, prefix_len=4,
                                         tails=(2, 2, 2))
        eng = _cont(model, params, block_size=2, num_blocks=13, max_running=3)
        ids = []
        for q in prompts:
            ids.append(eng.submit(q, 10))
            eng.step()
        fin = {r.req_id: r for r in eng.run()}
        assert sum(r.preemptions for r in fin.values()) > 0
        assert eng.metrics()["prefix_hit_tokens"] > 0
        for q, rid in zip(prompts, ids):
            np.testing.assert_array_equal(
                _oracle_tokens(model, params, q, 10),
                np.asarray(fin[rid].out_tokens))

    def test_prefix_cache_off_no_hits(self, smollm):
        """--prefix-cache off: identical traffic, zero hits, same tokens."""
        cfg, model, params = smollm
        rng = np.random.RandomState(5)
        prompts = _shared_prefix_prompts(cfg, rng, prefix_len=12, tails=(3, 5))
        eng = _cont(model, params, prefix_cache=False)
        out = _staggered(eng, prompts, [4, 4])
        assert eng.metrics()["prefix_hit_tokens"] == 0
        for p, got in zip(prompts, out):
            np.testing.assert_array_equal(
                _oracle_tokens(model, params, p, 4), got)

    def test_pool_lookup_token_exact(self, smollm):
        """Registry hits are token-exact: a one-token difference inside the
        first block kills the whole chain."""
        _, model, _ = smollm
        pool = BlockPool(model, num_blocks=16, block_size=4, max_requests=4,
                         dtype=jnp.float32, prefix_cache=True)
        toks = np.arange(10, dtype=np.int32)
        assert pool.alloc(1, 10, tokens=toks) == 0      # cold
        pool.commit(1, toks)
        same = pool.probe_prefix(toks)
        assert same == 8                                # 2 full blocks
        mutated = toks.copy()
        mutated[2] += 1
        assert pool.probe_prefix(mutated) == 0
        mutated = toks.copy()
        mutated[5] += 1                                 # second block differs
        assert pool.probe_prefix(mutated) == 4
        pool.free(1)
        assert pool.cached_blocks == 2                  # full blocks cached
        assert pool.probe_prefix(toks) == 8             # survive free


class TestPrefillBuckets:
    def test_prefill_compiles_bounded_by_length_buckets(self, smollm):
        """Recompile guard: a mixed-length trace (11 distinct prompt
        lengths) must compile at most one prefill per suffix-length bucket —
        not one per prompt length."""
        cfg, model, params = smollm
        rng = np.random.RandomState(6)
        lens = [3, 5, 6, 9, 11, 14, 17, 21, 24, 27, 30]
        prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
                   for l in lens]
        eng = _cont(model, params, num_blocks=256, max_running=4)
        for p in prompts:
            eng.submit(p, 3)
            eng.step()
        eng.run()
        m = eng.metrics()
        n_len_buckets = len({eng._bucket_prefill(l) for l in lens})
        assert n_len_buckets == 3                       # 8 / 16 / 32
        assert m["prefill_batches"] >= len(lens)
        assert m["prefill_compiles"] <= n_len_buckets, m
        assert m["prefill_shapes"] <= n_len_buckets

    def test_joiners_batched_into_one_prefill(self, smollm):
        """Same-bucket joiners admitted in one step prefill in ONE jitted
        call (batch > 1), and still match the oracle."""
        cfg, model, params = smollm
        rng = np.random.RandomState(7)
        prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
                   for l in (5, 6, 7)]
        eng = _cont(model, params)
        ids = [eng.submit(p, 4) for p in prompts]
        eng.step()                       # all three admitted together
        assert eng.metrics()["prefill_batches"] == 1
        eng.run()
        fin = {r.req_id: r for r in eng.finished}
        for p, rid in zip(prompts, ids):
            np.testing.assert_array_equal(
                _oracle_tokens(model, params, p, 4),
                np.asarray(fin[rid].out_tokens))
