"""Seeded chaos soak of the serving engine: preemption, fork, speculative
rollback, prefix eviction, and mid-trace hot-swaps, all interleaved.

Each trace drives a ``ContinuousEngine`` with randomized staggered arrivals
against a deliberately small paged pool (preemption + prefix-eviction churn),
randomly forks running requests (COW sharing), optionally serves
speculatively (draft+verify rollback via ``BlockPool.truncate``), and
hot-swaps bitwise-identical params mid-trace (the value-swap no-op). After
EVERY step the paged pool must satisfy the allocator conservation
invariants, and at the end every greedy request must match the fixed-batch
``ServeEngine`` oracle token-for-token — forked children included (greedy
children continue the parent's trajectory).

Every soak runs with a ``FlightRecorder`` attached; if a pool invariant
trips, the postmortem bundle (ring tail + metrics + config) is dumped
before the assertion propagates, so a red soak in CI ships the scheduling
history that led to it. A forced-failure test proves the bundle parses
and carries the hidden request's complete event history.

A short variant keeps the soak in tier-1; the full sweep (more seeds, more
requests, speculative lane) runs under ``-m slow``.
"""
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CompressConfig
from repro.configs import get_smoke_config
from repro.core.calibrate import calibrate_model
from repro.core.compress import compress_model
from repro.models import build_model
from repro.obs import EVENT_TYPES, FlightRecorder
from repro.serve import ContinuousEngine, ServeEngine


@pytest.fixture(scope="module")
def smollm():
    cfg = get_smoke_config("smollm_135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def draft_params(smollm):
    cfg, model, params = smollm
    rng = np.random.RandomState(3)
    cal = calibrate_model(
        model, params,
        [{"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 24)))}
         for _ in range(2)])
    dparams, _ = compress_model(
        model, params, cal, CompressConfig(method="coala", ratio=0.5,
                                           lam=4.0, mu=-1.0))
    return dparams


def _pool_invariants(pool, live_ids):
    """Allocator conservation after any step: free/cached/live partition the
    pool exactly, refcounts equal table membership, nothing leaks."""
    free = set(pool.free_block_ids())
    cached = set(pool.cached_block_ids())
    live, refs = set(), {}
    for rid in live_ids:
        for b in pool.table(rid):
            refs[b] = refs.get(b, 0) + 1
            live.add(b)
    assert 0 not in free | cached | live
    assert not (free & cached or free & live or cached & live)
    assert len(free) + len(cached) + len(live) == pool.usable_blocks, \
        (sorted(free), sorted(cached), sorted(live))
    for b in live:
        assert pool.ref_count(b) == refs[b], (b, pool.ref_count(b), refs[b])
    for b in free | cached:
        assert pool.ref_count(b) == 0


def _soak(cfg, model, params, *, seed, n_requests, dparams=None,
          swap=True, num_blocks=14, block_size=2, max_running=3,
          max_prompt=8, max_new=7, dump_path=None, sabotage_step=None):
    rng = np.random.RandomState(seed)
    # every soak records flight history; a tripped invariant dumps the
    # postmortem bundle before re-raising (default path: a temp dir, so a
    # green soak leaves no litter in the working tree)
    if dump_path is None:
        dump_path = os.path.join(tempfile.mkdtemp(prefix="soak_pm_"),
                                 "POSTMORTEM_soak.json")
    fl = FlightRecorder(capacity=4096, dump_path=dump_path)
    eng = ContinuousEngine(model, params, compute_dtype=jnp.float32,
                           cache_dtype=jnp.float32, block_size=block_size,
                           num_blocks=num_blocks, max_running=max_running,
                           draft_params=dparams, spec_k=2,
                           flight_recorder=fl)

    def check(pool, live_ids):
        try:
            _pool_invariants(pool, live_ids)
        except AssertionError:
            eng.dump_postmortem("pool_invariant")
            raise

    trace = []
    arrive = 0
    for _ in range(n_requests):
        prompt = rng.randint(0, cfg.vocab_size,
                             (rng.randint(2, max_prompt + 1),))
        trace.append((arrive, prompt.astype(np.int32),
                      int(rng.randint(2, max_new + 1))))
        arrive += int(rng.randint(0, 4))
    pending = list(trace)
    expected = {}                     # rid -> (prompt, n_expected_tokens)
    parents = {}                      # forked child rid -> parent rid
    swaps = forks = 0
    step = 0
    while pending or eng.has_work():
        while pending and pending[0][0] <= step:
            _, prompt, nn = pending.pop(0)
            rid = eng.submit(prompt, nn)
            expected[rid] = (prompt, nn)
        eng.step()
        live_ids = [r.req_id for r in eng.scheduler.running]
        if sabotage_step is not None and step >= sabotage_step and live_ids:
            # forced failure: hide a live request from the checker, so the
            # conservation count genuinely fails and the dump path fires
            live_ids = live_ids[1:]
        check(eng.pool, live_ids)
        if eng.draft_pool is not None:
            check(eng.draft_pool, live_ids)
        running = list(eng.scheduler.running)
        if (running and rng.randint(4) == 0
                and len(running) < max_running):
            parent = running[rng.randint(len(running))]
            try:
                child = eng.fork(parent.req_id)
            except (ValueError, MemoryError):
                pass                  # slot/pool full: engine said no cleanly
            else:
                forks += 1
                root = parents.get(parent.req_id, parent.req_id)
                parents[child] = root
                expected[child] = expected[root]
            check(eng.pool, [r.req_id for r in eng.scheduler.running])
        if swap and running and rng.randint(3) == 0:
            eng.hot_swap(
                jax.tree.map(jnp.copy, eng.params),
                jax.tree.map(jnp.copy, eng.draft_params)
                if dparams is not None else None)
            swaps += 1
        step += 1
        assert step < 2000, "soak failed to drain"
    eng.flush_stream()
    check(eng.pool, [])
    assert eng.pool.available_blocks == eng.pool.usable_blocks
    assert len(eng.finished) == len(expected)

    # greedy parity: every request (and every forked child — greedy forks
    # continue the parent's trajectory) matches the fixed-batch oracle
    oracle = ServeEngine(model, params, compute_dtype=jnp.float32,
                         cache_dtype=jnp.float32)
    fin = {r.req_id: r for r in eng.finished}
    checked = 0
    for rid, (prompt, nn) in expected.items():
        got = np.asarray(fin[rid].out_tokens)
        ref = np.asarray(oracle.generate(
            jnp.asarray(prompt)[None], max_new_tokens=nn))[0, len(prompt):]
        np.testing.assert_array_equal(
            ref[:len(got)], got,
            err_msg=f"request {rid} (seed {seed}) diverged from oracle")
        assert len(got) == nn, (rid, len(got), nn)
        checked += 1
    stats = dict(swaps=swaps, forks=forks, checked=checked,
                 preemptions=sum(r.preemptions for r in fin.values()),
                 evictions=int(eng.registry.get(
                     "pool_prefix_evictions_total").value),
                 flight_events=len(fl), flight_dropped=fl.dropped)
    return stats


def test_soak_fast(smollm):
    """Tier-1 variant: one seed, small trace, swaps + forks + preemption
    pressure, invariants every step, full greedy parity."""
    cfg, model, params = smollm
    stats = _soak(cfg, model, params, seed=0, n_requests=6)
    assert stats["swaps"] > 0
    assert stats["checked"] >= 6
    assert stats["flight_events"] > 0    # the recorder rode along


def test_soak_forced_failure_dumps_postmortem(smollm, tmp_path):
    """A tripped pool invariant must leave a parseable (strict-JSON)
    postmortem bundle carrying the complete event history of every
    in-flight request — the acceptance contract for red soaks in CI."""
    cfg, model, params = smollm
    dump = tmp_path / "POSTMORTEM_soak.json"
    with pytest.raises(AssertionError):
        _soak(cfg, model, params, seed=3, n_requests=4, swap=False,
              dump_path=str(dump), sabotage_step=2)
    with open(dump) as f:
        bundle = json.load(
            f, parse_constant=lambda c: pytest.fail(f"non-strict {c}"))
    assert bundle["reason"] == "pool_invariant"
    events = bundle["events"]
    assert events and bundle["dropped"] == 0
    assert all(e["event"] in EVENT_TYPES for e in events)
    seqs = [e["seq"] for e in events]
    assert seqs == sorted(seqs)
    # config + metrics snapshots ride along for the postmortem reader
    assert bundle["config"]["num_blocks"] == 14
    assert "slo_goodput" in bundle["metrics"]
    # complete histories: every admitted request's record starts at its
    # origin (submit, or fork for adopted children) — nothing truncated
    admitted = {e["req_id"] for e in events if e["event"] == "admit"}
    assert admitted
    for rid in admitted:
        hist = [e["event"] for e in events if e.get("req_id") == rid]
        assert hist[0] in ("submit", "fork"), (rid, hist)
        assert "admit" in hist


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(4))
def test_soak_sweep(smollm, seed):
    """Full sweep: longer traces under a tighter pool (guaranteed eviction
    and preemption churn), per-seed randomized fork/swap interleavings."""
    cfg, model, params = smollm
    stats = _soak(cfg, model, params, seed=seed, n_requests=10,
                  num_blocks=12, max_new=8)
    assert stats["swaps"] > 0
    assert stats["checked"] >= 10


@pytest.mark.slow
def test_soak_speculative(smollm, draft_params):
    """Speculative lane: draft+verify rounds roll rejected pages back via
    truncate every step, while forks, identity hot-swaps of BOTH param
    sets, and preemption run interleaved; both pools hold conservation,
    greedy stays token-exact vs the non-speculative oracle."""
    cfg, model, params = smollm
    stats = _soak(cfg, model, params, seed=1, n_requests=8,
                  dparams=draft_params, num_blocks=16)
    assert stats["swaps"] > 0
    assert stats["checked"] >= 8
