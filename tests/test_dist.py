"""Distributed behaviours on fake multi-device meshes (subprocess: device
count is locked at jax init, so each scenario runs in its own interpreter)."""
import os
import subprocess
import sys
import textwrap

# These subprocesses exercise `repro.dist` (sharding specs + the
# jax.shard_map/AxisType compat shims installed on `import repro`) on 8 fake
# host devices. They were xfail(strict=False) from the seed commit until the
# subsystem landed; they now assert for real. Nothing here is unsupported on
# the pinned jax 0.4.37 — the shims in repro/dist/compat.py close the gap.

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


class TestDistributedTSQR:
    def test_butterfly_equals_serial(self):
        run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.core.tsqr import distributed_tsqr_r, qr_r, square_r
            mesh = jax.make_mesh((8,), ("data",),
                                 axis_types=(jax.sharding.AxisType.Auto,))
            xt = jax.random.normal(jax.random.PRNGKey(0), (128, 24))
            f = jax.jit(jax.shard_map(lambda x: distributed_tsqr_r(x, "data"),
                                      mesh=mesh, in_specs=P("data", None),
                                      out_specs=P(), check_vma=False))
            r = f(xt)
            np.testing.assert_allclose(np.asarray(r),
                                       np.asarray(square_r(qr_r(xt))),
                                       rtol=2e-4, atol=2e-4)
            print("OK")
        """)


class TestMoEShardMap:
    def test_sharded_matches_local(self):
        run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_smoke_config
            from repro.models import ffn as ffn_lib
            from repro.models.common import ParallelCtx
            cfg = get_smoke_config("deepseek_moe_16b")
            mesh = jax.make_mesh((2, 4), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            params = ffn_lib.moe_init(jax.random.PRNGKey(0), cfg)
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
            y_loc, aux_loc = ffn_lib.moe_apply(cfg, params, x, ctx=ParallelCtx())
            ctx = ParallelCtx(mesh=mesh, batch_axes=("data",),
                              shard_map_moe=True)
            y_shd, aux_shd = jax.jit(
                lambda p, x: ffn_lib.moe_apply(cfg, p, x, ctx=ctx))(params, x)
            # same routing math; capacity differs (per-shard), so compare
            # loosely on values and tightly on shapes/finite-ness
            assert y_shd.shape == y_loc.shape
            assert np.all(np.isfinite(np.asarray(y_shd)))
            diff = np.abs(np.asarray(y_shd) - np.asarray(y_loc)).max()
            scale = np.abs(np.asarray(y_loc)).max()
            assert diff < 0.3 * scale, (diff, scale)
            print("OK")
        """)

    def test_sharded_exact_with_full_capacity(self):
        run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np, dataclasses
            from repro.configs import get_smoke_config
            from repro.models import ffn as ffn_lib
            from repro.models.common import ParallelCtx
            cfg = get_smoke_config("jamba_v0_1_52b")
            mesh = jax.make_mesh((2, 4), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            params = ffn_lib.moe_init(jax.random.PRNGKey(0), cfg)
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
            ctx0 = ParallelCtx(moe_capacity_factor=64.0)
            y_loc, _ = ffn_lib.moe_apply(cfg, params, x, ctx=ctx0)
            ctx = ParallelCtx(mesh=mesh, batch_axes=("data",),
                              shard_map_moe=True, moe_capacity_factor=64.0)
            y_shd, _ = jax.jit(
                lambda p, x: ffn_lib.moe_apply(cfg, p, x, ctx=ctx))(params, x)
            np.testing.assert_allclose(np.asarray(y_shd), np.asarray(y_loc),
                                       rtol=2e-3, atol=2e-3)
            print("OK")
        """)


class TestGradCompression:
    def test_compressed_mean_close_and_error_feedback_accumulates(self):
        run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.train import grad_compress as gc
            mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*3)

            def loss_and_grad(params, batch):
                # per-pod quadratic: grads differ across pods via the batch
                def loss(p):
                    return jnp.mean((p["w"] * batch["x"] - 1.0) ** 2)
                l, g = jax.value_and_grad(loss)(params)
                return (l, {"ce": l, "aux": jnp.zeros(())}), g

            f = gc.make_compressed_grads_fn(
                loss_and_grad, mesh,
                lambda leaf: P("pod", *([None] * (leaf.ndim - 1))))
            params = {"w": jnp.ones((256,))}
            batch = {"x": jnp.concatenate([jnp.ones((2, 256)),
                                           2 * jnp.ones((2, 256))])}
            err = gc.init_error_state(params, 2)
            loss, metrics, grads, new_err = jax.jit(f)(params, batch, err)
            # true mean-of-pod-grads: per pod, loss = mean over (2,256)
            # elements; d/dw_i = (1/(2*256)) * sum_rows 2*(w_i*x-1)*x
            g1 = 2 * 2 * (1.0 - 1.0) * 1.0 / 512     # pod 0 (x=1): 0
            g2 = 2 * 2 * (2.0 - 1.0) * 2.0 / 512     # pod 1 (x=2)
            want = (g1 + g2) / 2
            np.testing.assert_allclose(np.asarray(grads["w"]),
                                       want * np.ones(256), rtol=2e-2,
                                       atol=1e-4)
            np.testing.assert_allclose(float(loss), 0.5, rtol=1e-5)
            assert new_err["w"].shape == (2, 256)
            print("OK")
        """)


class TestShardedTrainStep:
    def test_small_mesh_train_step_runs(self):
        run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_smoke_config
            from repro.models import build_model
            from repro.models.common import ParallelCtx
            from repro.config import TrainConfig
            from repro.dist.sharding import param_specs, batch_specs, to_named, batch_axes_of
            from repro.train.train_loop import make_train_step, make_train_state
            from jax.sharding import PartitionSpec as P
            mesh = jax.make_mesh((2, 4), ("data", "model"),
                                 axis_types=(jax.sharding.AxisType.Auto,)*2)
            cfg = get_smoke_config("smollm_135m")
            model = build_model(cfg)
            tcfg = TrainConfig(microbatches=2, remat="full")
            ctx = ParallelCtx(mesh=mesh, batch_axes=batch_axes_of(mesh))
            state = make_train_state(model, tcfg, jax.random.PRNGKey(0))
            batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                                  (4, 64), 0, cfg.vocab_size)}
            pspecs = param_specs(cfg, state["params"], mesh, mode="train")
            sspecs = {"params": pspecs, "opt": {"m": pspecs, "v": pspecs,
                                                "step": P()}}
            bspecs = batch_specs(cfg, batch, mesh)
            step = make_train_step(model, tcfg, ctx, mesh=mesh)
            jstep = jax.jit(step, in_shardings=(to_named(sspecs, mesh),
                                                to_named(bspecs, mesh)))
            new_state, metrics = jstep(state, batch)
            assert np.isfinite(float(metrics["loss"]))
            assert int(new_state["opt"]["step"]) == 1
            print("OK")
        """)
