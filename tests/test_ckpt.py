"""Checkpointing: roundtrip, atomicity, keep-k, async, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager


def _state(key=0):
    k = jax.random.PRNGKey(key)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "blocks": [jnp.ones((4,)), jnp.zeros((2, 2))]},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    mgr.save(7, state)
    restored, meta = mgr.restore(state)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, _state(1), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_keep_k_prunes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
    assert mgr.all_steps() == [3, 4]


def test_restore_latest_and_specific(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    s1, s2 = _state(1), _state(2)
    mgr.save(1, s1)
    mgr.save(2, s2)
    r2, _ = mgr.restore(s1)                      # latest = step 2
    np.testing.assert_array_equal(np.asarray(r2["params"]["w"]),
                                  np.asarray(s2["params"]["w"]))
    r1, _ = mgr.restore(s1, step=1)
    np.testing.assert_array_equal(np.asarray(r1["params"]["w"]),
                                  np.asarray(s1["params"]["w"]))


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp dir naming means a crashed write is never listed as a step."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    os.makedirs(os.path.join(str(tmp_path), ".tmp_step_9"))
    assert mgr.all_steps() == []


def test_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    with pytest.raises(AssertionError):
        mgr.restore({"different": jnp.zeros((1,))})


def test_elastic_reshard_restore(tmp_path):
    """Restore a checkpoint saved on one mesh onto a DIFFERENT mesh (elastic
    up/down-scaling): leaves are stored unsharded and device_put under the
    new mesh's shardings."""
    import subprocess
    import sys
    import textwrap
    env_dir = str(tmp_path)
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import CheckpointManager
        mgr = CheckpointManager({env_dir!r}, keep=2)
        state = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        # save from a (4,2) mesh sharding
        mesh_a = jax.make_mesh((4, 2), ("data", "model"),
                               axis_types=(jax.sharding.AxisType.Auto,)*2)
        sharded = jax.device_put(state, {{"w": NamedSharding(
            mesh_a, P("data", "model"))}})
        mgr.save(3, sharded)
        # restore onto a DIFFERENT (2, 4) mesh
        mesh_b = jax.make_mesh((2, 4), ("data", "model"),
                               axis_types=(jax.sharding.AxisType.Auto,)*2)
        restored, meta = mgr.restore(
            state, shardings={{"w": NamedSharding(mesh_b, P("model", "data"))}})
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))
        assert restored["w"].sharding.mesh.shape["data"] == 2
        print("OK")
    """)
    env = dict(os.environ)
    import os as _os
    repo = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
    env["PYTHONPATH"] = _os.path.join(repo, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
