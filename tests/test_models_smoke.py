"""Per-architecture smoke tests: reduced config, one forward/train step on CPU.

Asserts output shapes and absence of NaNs — per the assignment, the FULL
configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build_model


def _batch_for(cfg, b=2, t=32, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, t), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ks[1], (b, cfg.n_audio_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            ks[2], (b, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    assert float(metrics["ce"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch_for(cfg, key=1)

    @jax.jit
    def step(p, b):
        def lf(p):
            return model.loss(p, b)[0]
        loss, grads = jax.value_and_grad(lf)(p)
        return loss, grads

    loss, grads = step(params, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g))), f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ["smollm_135m", "gemma2_27b", "jamba_v0_1_52b",
                                  "xlstm_1_3b", "deepseek_v2_lite_16b",
                                  "whisper_base", "qwen2_vl_2b"])
def test_decode_matches_forward(arch):
    """prefill + decode_step must agree with the full forward pass."""
    cfg = get_smoke_config(arch)
    if cfg.family == "vlm":
        pytest.skip("vlm decode parity covered via serve tests (vision prefix)")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    b, t = 2, 16
    batch = _batch_for(cfg, b=b, t=t, key=2)
    tokens = batch["tokens"]
    # capacity large enough that the MoE router drops no tokens — otherwise
    # prefill(15)+decode(1) legitimately differs from prefill(16)
    from repro.models.common import ParallelCtx
    ctx = ParallelCtx(moe_capacity_factor=16.0)

    cache = model.init_cache(b, 64, dtype=jnp.float32)
    kw = {"frames": batch["frames"]} if cfg.family == "encdec" else {}
    logits_pre, cache = jax.jit(
        lambda p, tk, c, **k: model.prefill(p, tk, c, ctx=ctx,
                                            compute_dtype=jnp.float32, **k)
    )(params, tokens[:, :t - 1], cache, **kw)

    dec = jax.jit(lambda p, tk, c, pos: model.decode_step(
        p, tk, c, pos, ctx=ctx, compute_dtype=jnp.float32))
    logits_dec, cache = dec(params, tokens[:, t - 1:t], cache,
                            jnp.asarray(t - 1, jnp.int32))
    assert np.all(np.isfinite(np.asarray(logits_dec)))

    # Reference: decode token t-1 by prefilling the full prefix
    cache2 = model.init_cache(b, 64, dtype=jnp.float32)
    logits_ref, _ = jax.jit(
        lambda p, tk, c, **k: model.prefill(p, tk, c, ctx=ctx,
                                            compute_dtype=jnp.float32, **k)
    )(params, tokens, cache2, **kw)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_ref)[:, -1], rtol=2e-3, atol=2e-3)
