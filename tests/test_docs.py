"""Docs health: the same link/anchor/flag checker CI's docs job runs.

Keeps README.md + docs/ honest from the tier-1 suite too: intra-repo
links and anchors must resolve, and every ``launch/serve.py`` argparse
flag must be documented in docs/serving.md.
"""
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_docs_links_anchors_and_serving_flags():
    r = subprocess.run([sys.executable, str(ROOT / "tools" / "check_docs.py")],
                       capture_output=True, text=True)
    assert r.returncode == 0, f"\n{r.stdout}{r.stderr}"


def test_checker_catches_dead_links(tmp_path):
    """The checker itself must not be vacuously green: a dead link in a
    doc copy has to fail."""
    import shutil
    root = tmp_path / "repo"
    (root / "src" / "repro" / "launch").mkdir(parents=True)
    (root / "tools").mkdir()
    (root / "docs").mkdir()
    shutil.copy(ROOT / "tools" / "check_docs.py", root / "tools")
    shutil.copy(ROOT / "src" / "repro" / "launch" / "serve.py",
                root / "src" / "repro" / "launch")
    shutil.copy(ROOT / "docs" / "serving.md", root / "docs")
    (root / "README.md").write_text("[gone](docs/missing.md)\n")
    r = subprocess.run([sys.executable, str(root / "tools" / "check_docs.py")],
                       capture_output=True, text=True)
    assert r.returncode == 1
    assert "dead link" in r.stdout
