"""Continuous-batching serving subsystem: block pool, scheduler, engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import BlockPool, ContinuousEngine, Request, Scheduler, \
    ServeEngine


@pytest.fixture(scope="module")
def smollm():
    cfg = get_smoke_config("smollm_135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _pool(model, **kw):
    kw.setdefault("num_blocks", 16)
    kw.setdefault("block_size", 4)
    kw.setdefault("max_requests", 4)
    kw.setdefault("dtype", jnp.float32)
    return BlockPool(model, **kw)


# ---------------------------------------------------------------------------
# Block pool
# ---------------------------------------------------------------------------

class TestBlockPool:
    def test_alloc_extend_free_invariants(self, smollm):
        _, model, _ = smollm
        pool = _pool(model)
        assert pool.free_blocks == 15          # block 0 reserved as trash
        pool.alloc(1, 10)                      # ceil(10/4) = 3 blocks
        assert len(pool.table(1)) == 3
        assert pool.free_blocks == 12
        pool.extend(1, 12)                     # still 3 blocks
        assert len(pool.table(1)) == 3
        pool.extend(1, 13)                     # crosses a block boundary
        assert len(pool.table(1)) == 4
        assert pool.free_blocks == 11
        assert 0 not in pool.table(1)          # trash block never handed out
        pool.alloc(2, 4)
        assert set(pool.table(1)).isdisjoint(pool.table(2))
        pool.free(1)
        pool.free(2)
        assert pool.free_blocks == 15          # everything returned

    def test_exhaustion_raises(self, smollm):
        _, model, _ = smollm
        pool = _pool(model, num_blocks=4)      # 3 usable blocks
        pool.alloc(1, 12)
        assert not pool.can_alloc(1)
        with pytest.raises(MemoryError):
            pool.alloc(2, 4)
        with pytest.raises(MemoryError):
            pool.extend(1, 13)
        pool.free(1)
        assert pool.can_alloc(12)

    def test_slot_exhaustion(self, smollm):
        _, model, _ = smollm
        pool = _pool(model, max_requests=1)
        pool.alloc(1, 4)
        assert not pool.can_alloc(4)           # blocks free, but no slot
        pool.free(1)
        assert pool.can_alloc(4)

    def test_gather_matches_scatter(self, smollm):
        """Round trip: a prefilled contiguous cache survives pool storage."""
        _, model, _ = smollm
        pool = _pool(model)
        pool.alloc(5, 10)
        nb = len(pool.table(5))
        ref = model.init_cache(1, nb * pool.block_size, dtype=jnp.float32)
        ref = jax.tree.map(
            lambda a: jax.random.normal(jax.random.PRNGKey(a.size % 97),
                                        a.shape, jnp.float32), ref)
        pool.scatter_prefill([5], ref, 10)
        got = pool.gather_batch([5])
        for sp, r, g in zip(pool.layout.specs, jax.tree.leaves(ref),
                            jax.tree.leaves(got)):
            if sp.token_axis is None:
                np.testing.assert_allclose(np.asarray(r), np.asarray(g))
            else:
                idx = [slice(None)] * r.ndim
                idx[sp.token_axis] = slice(0, nb * pool.block_size)
                np.testing.assert_allclose(np.asarray(r[tuple(idx)]),
                                           np.asarray(g[tuple(idx)]))

    def test_reused_blocks_read_zero(self, smollm):
        _, model, _ = smollm
        pool = _pool(model)
        pool.alloc(1, 8)
        ref = model.init_cache(1, 8, dtype=jnp.float32)
        ref = jax.tree.map(lambda a: jnp.ones(a.shape, jnp.float32), ref)
        pool.scatter_prefill([1], ref, 8)
        pool.free(1)
        pool.alloc(2, 8)                       # reuses the freed blocks
        got = pool.gather_batch([2])
        for sp, g in zip(pool.layout.specs, jax.tree.leaves(got)):
            if sp.token_axis is not None:
                assert float(jnp.abs(g).max()) == 0.0

    def test_layout_probe_families(self):
        """Probe classifies token-axis vs per-request-state leaves across
        decoder-only, enc-dec, and recurrent cache layouts."""
        n_token = {}
        for arch in ("smollm_135m", "whisper_base", "xlstm_1_3b"):
            model = build_model(get_smoke_config(arch))
            pool = _pool(model)
            n_token[arch] = sum(1 for s in pool.layout.specs
                                if s.token_axis is not None)
        assert n_token["smollm_135m"] > 0      # K/V pages
        assert n_token["whisper_base"] > 0     # self-attn pages (+cross state)
        assert n_token["xlstm_1_3b"] == 0      # purely recurrent state


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

def _req(rid, t0=4, new=4, **kw):
    return Request(req_id=rid, prompt=np.zeros((t0,), np.int32),
                   max_new_tokens=new, **kw)


class TestScheduler:
    def test_join_and_evict_mixed_lengths(self, smollm):
        _, model, _ = smollm
        pool = _pool(model, num_blocks=32, max_requests=8)
        sched = Scheduler(pool, max_running=2)
        reqs = [_req(i, t0=3 + 5 * i, new=2 + i) for i in range(4)]
        for r in reqs:
            sched.submit(r)
        admitted = sched.admit()
        assert [r.req_id for r in admitted] == [0, 1]   # FIFO, slot cap
        for r in admitted:
            pool.alloc(r.req_id, r.vis_offset + len(r.prompt))
        assert sched.admit() == []                      # running set full
        sched.evict(reqs[0])
        assert reqs[0].state == "finished"
        nxt = sched.admit()
        assert [r.req_id for r in nxt] == [2]           # joins immediately
        pool.alloc(2, len(reqs[2].prompt))
        assert len(sched.running) == 2

    def test_admission_respects_capacity(self, smollm):
        _, model, _ = smollm
        pool = _pool(model, num_blocks=4)               # 3 usable blocks
        sched = Scheduler(pool, max_running=4)
        sched.submit(_req(0, t0=8, new=4))              # budget 12 -> 3 blocks
        sched.submit(_req(1, t0=8, new=4))
        admitted = sched.admit()
        assert [r.req_id for r in admitted] == [0]      # no blocks for #1
        pool.alloc(0, 8)
        assert sched.admit() == []

    def test_preempt_youngest_requeues_front(self, smollm):
        _, model, _ = smollm
        pool = _pool(model, num_blocks=32, max_requests=8)
        sched = Scheduler(pool, max_running=4)
        for i in range(3):
            sched.submit(_req(i))
        for r in sched.admit():
            pool.alloc(r.req_id, 4)
        victim = sched.preempt_youngest()
        assert victim.req_id == 2
        assert victim.preemptions == 1
        assert sched.waiting[0] is victim               # front of the queue
        assert len(sched.running) == 2


# ---------------------------------------------------------------------------
# Engine: greedy token-equivalence with the legacy fixed-batch path
# ---------------------------------------------------------------------------

def _engines(model, params, **kw):
    leg = ServeEngine(model, params, compute_dtype=jnp.float32,
                      cache_dtype=jnp.float32)
    kw.setdefault("block_size", 4)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_running", 4)
    cont = ContinuousEngine(model, params, compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32, **kw)
    return leg, cont


class TestContinuousEngine:
    def test_greedy_equivalence_uniform_batch(self, smollm):
        cfg, model, params = smollm
        leg, cont = _engines(model, params)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (3, 7), 0,
                                    cfg.vocab_size)
        a = np.asarray(leg.generate(prompt, max_new_tokens=5))
        b = np.asarray(cont.generate(prompt, max_new_tokens=5))
        np.testing.assert_array_equal(a, b)

    def test_greedy_equivalence_mixed_length_trace(self, smollm):
        """Staggered arrivals, varied prompt/output lengths: every request
        must match a solo run of the legacy engine token-for-token."""
        cfg, model, params = smollm
        leg, cont = _engines(model, params, max_running=3)
        rng = np.random.RandomState(0)
        lens, news = [3, 9, 5, 12], [5, 3, 7, 2]
        prompts = [rng.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
                   for l in lens]
        ids = []
        for i, (p, n) in enumerate(zip(prompts, news)):
            ids.append(cont.submit(p, n))
            cont.step()                       # staggered: join mid-decode
        cont.run()
        fin = {r.req_id: r for r in cont.finished}
        for p, n, rid in zip(prompts, news, ids):
            ref = np.asarray(leg.generate(jnp.asarray(p)[None],
                                          max_new_tokens=n))[0, len(p):]
            np.testing.assert_array_equal(
                ref, np.asarray(fin[rid].out_tokens),
                err_msg=f"request {rid} diverged from fixed-batch path")

    def test_preemption_preserves_greedy_tokens(self, smollm):
        """A pool too small for the full load forces preemption; preempted
        requests must still finish on the same greedy trajectory."""
        cfg, model, params = smollm
        leg, cont = _engines(model, params, block_size=2, num_blocks=9,
                             max_running=3)
        rng = np.random.RandomState(1)
        prompts = [rng.randint(0, cfg.vocab_size, (4,)).astype(np.int32)
                   for _ in range(3)]
        ids = [cont.submit(p, 6) for p in prompts]
        fin = {r.req_id: r for r in cont.run()}
        assert sum(r.preemptions for r in fin.values()) > 0
        for p, rid in zip(prompts, ids):
            ref = np.asarray(leg.generate(jnp.asarray(p)[None],
                                          max_new_tokens=6))[0, 4:]
            np.testing.assert_array_equal(ref,
                                          np.asarray(fin[rid].out_tokens))

    def test_eos_termination_and_metrics(self, smollm):
        cfg, model, params = smollm
        _, cont = _engines(model, params)
        prompt = np.zeros((4,), np.int32)
        # find what greedy emits first, then use it as the EOS id
        probe = cont.submit(prompt, 1)
        first = cont.run()[0].out_tokens[0]
        cont2 = _engines(model, params)[1]
        rid = cont2.submit(prompt, 10, eos_id=first)
        fin = cont2.run()
        assert fin[0].req_id == rid
        assert fin[0].out_tokens[-1] == first
        assert len(fin[0].out_tokens) < 10
        m = cont2.metrics()
        assert m["requests"] == 1
        assert m["mean_ttft_s"] >= 0.0
        assert m["tokens_per_sec"] > 0.0

    def test_greedy_equivalence_vlm(self):
        """Both engines place the vision prefix in the cache identically."""
        cfg = get_smoke_config("qwen2_vl_2b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        leg, cont = _engines(model, params)
        extras = {"vision_embeds": jax.random.normal(
            jax.random.PRNGKey(9), (2, cfg.n_vision_tokens, cfg.d_model))}
        prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                                    cfg.vocab_size)
        a = np.asarray(leg.generate(prompt, max_new_tokens=4, extras=extras))
        b = np.asarray(cont.generate(prompt, max_new_tokens=4, extras=extras))
        np.testing.assert_array_equal(a, b)

    def test_submit_rejects_impossible_request(self, smollm):
        """A request whose worst case can never fit the pool must fail fast
        at submit, not spin forever in the admission queue."""
        cfg, model, params = smollm
        _, cont = _engines(model, params, block_size=4, num_blocks=2)
        with pytest.raises(ValueError, match="blocks"):
            cont.submit(np.zeros((16,), np.int32), 4)

    def test_per_request_temperature(self, smollm):
        """Greedy and sampled requests coexist in one batch; the greedy row
        stays on the deterministic trajectory."""
        cfg, model, params = smollm
        leg, cont = _engines(model, params)
        rng = np.random.RandomState(2)
        p = rng.randint(0, cfg.vocab_size, (5,)).astype(np.int32)
        gid = cont.submit(p, 5, temperature=0.0)
        sid = cont.submit(p, 5, temperature=1.5, seed=7)
        fin = {r.req_id: r for r in cont.run()}
        ref = np.asarray(leg.generate(jnp.asarray(p)[None],
                                      max_new_tokens=5))[0, 5:]
        np.testing.assert_array_equal(ref, np.asarray(fin[gid].out_tokens))
        assert len(fin[sid].out_tokens) == 5
