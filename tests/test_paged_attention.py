"""Paged-attention decode kernel vs oracles (interpret=True on CPU).

Three-way parity: the Pallas kernel (interpret mode — the exact program
Mosaic would lower on TPU), the ``jax.nn`` reference fallback, and a dense
numpy oracle that materializes each request's contiguous KV prefix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.paged_attention import paged_attention, paged_attention_ref


def _case(key, *, b, hq, hkv, hd, bs, num_blocks, lengths, dtype=jnp.float32):
    """Build a random pool + block tables covering ``lengths`` per request."""
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (b, hq, hd), jnp.float32).astype(dtype)
    k_pages = jax.random.normal(ks[1], (num_blocks, bs, hkv, hd),
                                jnp.float32).astype(dtype)
    v_pages = jax.random.normal(ks[2], (num_blocks, bs, hkv, hd),
                                jnp.float32).astype(dtype)
    # hand out distinct non-trash blocks round-robin; pad rows with block 0
    nb = max(-(-max(lengths, default=1) // bs), 1)
    tables = np.zeros((b, nb), np.int32)
    nxt = 1
    for i, ln in enumerate(lengths):
        for j in range(-(-ln // bs)):
            tables[i, j] = nxt
            nxt += 1
    assert nxt <= num_blocks, "test pool too small"
    return q, k_pages, v_pages, jnp.asarray(tables), \
        jnp.asarray(lengths, jnp.int32)


def _dense_oracle(q, k_pages, v_pages, tables, lengths, *, scale=None,
                  cap=0.0, window=0):
    """Per-request contiguous softmax attention in fp64."""
    q = np.asarray(q, np.float64)
    kp = np.asarray(k_pages, np.float64)
    vp = np.asarray(v_pages, np.float64)
    tables = np.asarray(tables)
    lengths = np.asarray(lengths)
    b, hq, hd = q.shape
    bs, hkv = kp.shape[1], kp.shape[2]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    out = np.zeros_like(q)
    for i in range(b):
        ln = int(lengths[i])
        if ln == 0:
            continue
        k = kp[tables[i]].reshape(-1, hkv, hd)[:ln]      # (ln, hkv, hd)
        v = vp[tables[i]].reshape(-1, hkv, hd)[:ln]
        lo = max(0, ln - window) if window > 0 else 0
        for h in range(hq):
            s = (k[lo:, h // g] @ q[i, h]) * scale
            if cap > 0:
                s = cap * np.tanh(s / cap)
            p = np.exp(s - s.max())
            p /= p.sum()
            out[i, h] = p @ v[lo:, h // g]
    return out


CASES = [
    # (hq, hkv, lengths, bs, cap, window)
    (4, 2, [5, 12, 1], 4, 0.0, 0),        # GQA, ragged, partial blocks
    (3, 1, [8, 3], 4, 0.0, 0),            # MQA-style sharing (g=3)
    (2, 2, [7, 16, 9, 2], 8, 0.0, 0),     # MHA, bs=8
    (4, 2, [20, 11], 4, 50.0, 0),         # logit softcap (gemma2)
    (4, 2, [20, 6, 13], 4, 0.0, 8),       # sliding window
    (4, 2, [19, 5], 4, 30.0, 6),          # window + cap together
]


@pytest.mark.parametrize("hq,hkv,lengths,bs,cap,window", CASES)
def test_kernel_matches_dense_oracle(hq, hkv, lengths, bs, cap, window):
    q, kp, vp, tables, lens = _case(0, b=len(lengths), hq=hq, hkv=hkv,
                                    hd=16, bs=bs, num_blocks=16,
                                    lengths=lengths)
    want = _dense_oracle(q, kp, vp, tables, lens, cap=cap, window=window)
    got = paged_attention(q, kp, vp, tables, lens, cap=cap, window=window,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)
    got_ref = paged_attention_ref(q, kp, vp, tables, lens, cap=cap,
                                  window=window)
    np.testing.assert_allclose(np.asarray(got_ref), want, rtol=2e-5,
                               atol=2e-5)


def test_zero_length_rows_are_zero_and_finite():
    """Bucket-padding rows (length 0, all-trash table) must not NaN."""
    q, kp, vp, tables, lens = _case(1, b=3, hq=4, hkv=2, hd=8, bs=4,
                                    num_blocks=8, lengths=[6, 0, 0])
    for fn in (lambda: paged_attention(q, kp, vp, tables, lens,
                                       interpret=True),
               lambda: paged_attention_ref(q, kp, vp, tables, lens)):
        out = np.asarray(fn())
        assert np.all(np.isfinite(out))
        np.testing.assert_array_equal(out[1:], 0.0)


def test_trash_block_padding_is_ignored():
    """Rows whose tables are padded with block 0 must not read it."""
    q, kp, vp, tables, lens = _case(2, b=2, hq=2, hkv=1, hd=8, bs=4,
                                    num_blocks=8, lengths=[3, 11])
    # poison the trash block: if any masked position leaks, outputs change
    kp2 = kp.at[0].set(1e4)
    vp2 = vp.at[0].set(1e4)
    a = paged_attention(q, kp, vp, tables, lens, interpret=True)
    bb = paged_attention(q, kp2, vp2, tables, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=1e-6)


def test_ops_dispatch_ref_on_cpu():
    """ops.paged_attention auto-routes to the jax.nn fallback off-TPU."""
    q, kp, vp, tables, lens = _case(3, b=2, hq=4, hkv=2, hd=8, bs=4,
                                    num_blocks=8, lengths=[5, 9])
    auto = ops.paged_attention(q, kp, vp, tables, lens)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(ref))


@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("hd", [16, 64])
def test_kernel_dtype_sweep(dtype, hd):
    q, kp, vp, tables, lens = _case(4, b=4, hq=4, hkv=2, hd=hd, bs=8,
                                    num_blocks=16, lengths=[25, 7, 16, 1],
                                    dtype=dtype)
    want = _dense_oracle(q, kp, vp, tables, lens)
    got = paged_attention(q, kp, vp, tables, lens, interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=tol, atol=tol)
