"""Property-based fuzz of the refcounted, prefix-cached block allocator.

Random alloc / append / fork / free (+ implicit COW and LRU-evict) sequences
run against a plain-Python reference model. After every operation the pool
must satisfy the allocator invariants:

  * refcounts exact: every live block's refcount equals the number of
    request tables referencing it (so never negative, never leaked);
  * disjointness: the free list, the cached-LRU set, and the live set
    partition the pool (trash block 0 in none of them);
  * conservation: free + cached + distinct-live == usable blocks;
  * table sizing: a request's table covers exactly ceil(len/bs) blocks;
  * token-exact lookups: a cached-prefix hit of ``c`` tokens implies some
    earlier request committed *exactly* those ``c`` tokens (never a hash
    alias), block-aligned and capped at len-1.

A tiny vocabulary and block size force heavy prefix collisions, fork chains
and eviction churn. With ``hypothesis`` installed the trace seeds are driven
by ``@given``; without it a fixed seed sweep keeps the fuzz in tier-1.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve import BlockPool

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

VOCAB = 3          # tiny alphabet -> dense prefix collisions
BS = 2             # block size
NUM_BLOCKS = 12
MAX_REQS = 5


@pytest.fixture(scope="module")
def model():
    return build_model(get_smoke_config("smollm_135m"))


def _pool(model):
    return BlockPool(model, num_blocks=NUM_BLOCKS, block_size=BS,
                     max_requests=MAX_REQS, dtype=jnp.float32,
                     prefix_cache=True)


def _check_invariants(pool, live_tokens):
    free = set(pool.free_block_ids())
    cached = set(pool.cached_block_ids())
    tables = {rid: pool.table(rid) for rid in live_tokens}
    live = set()
    refs = {}
    for t in tables.values():
        for b in t:
            refs[b] = refs.get(b, 0) + 1
            live.add(b)
    # trash block 0 is reserved everywhere
    assert 0 not in free and 0 not in cached and 0 not in live
    # a block is in exactly one of {free, cached, live}
    assert not free & cached
    assert not free & live
    assert not cached & live
    # conservation: nothing leaks, nothing double-counted
    assert len(free) + len(cached) + len(live) == pool.usable_blocks, \
        (sorted(free), sorted(cached), sorted(live))
    assert pool.available_blocks == len(free) + len(cached)
    # refcounts match table membership exactly (=> never negative)
    for b in live:
        assert pool.ref_count(b) == refs[b], (b, pool.ref_count(b), refs[b])
    for b in free | cached:
        assert pool.ref_count(b) == 0
    # tables sized to their token streams, no intra-table duplicates
    for rid, toks in live_tokens.items():
        assert len(tables[rid]) == pool.blocks_for(len(toks))
        assert len(set(tables[rid])) == len(tables[rid])


def _run_trace(model, seed, n_ops=60):
    rng = np.random.RandomState(seed)
    pool = _pool(model)
    live = {}                 # rid -> token list (may have uncommitted tail)
    clen = {}                 # rid -> committed token count (block-aligned)
    committed = set()         # every block-aligned prefix ever committed
    next_id = 0

    def commit(rid):
        toks = np.asarray(live[rid], np.int32)
        pool.commit(rid, toks)
        clen[rid] = (len(toks) // BS) * BS
        for k in range(1, len(toks) // BS + 1):
            committed.add(tuple(int(t) for t in toks[:k * BS]))

    for _ in range(n_ops):
        op = rng.randint(5)
        if op == 0:                                    # alloc (prefill)
            toks = rng.randint(0, VOCAB, (rng.randint(1, 9),))
            rid = next_id
            try:
                c = pool.alloc(rid, len(toks), tokens=toks)
            except MemoryError:
                _check_invariants(pool, live)          # clean rollback
                continue
            next_id += 1
            # hits are block-aligned, leave >= 1 token to prefill, and are
            # token-exact against something committed earlier
            assert c % BS == 0 and 0 <= c <= ((len(toks) - 1) // BS) * BS
            if c:
                assert tuple(int(t) for t in toks[:c]) in committed
            live[rid] = [int(t) for t in toks]
            commit(rid)
        elif op == 1 and live:                         # append (decode step)
            rid = list(live)[rng.randint(len(live))]
            live[rid].append(int(rng.randint(VOCAB)))
            try:
                pool.extend(rid, len(live[rid]))
            except MemoryError:                        # engine would preempt
                live[rid].pop()
                pool.free(rid)
                del live[rid], clen[rid]
                continue
            # skipping commit half the time models a speculative run's
            # written-but-uncommitted tail (verify writes, then rollback)
            if rng.randint(2):
                commit(rid)
        elif op == 2 and live:                         # fork (best-of-n)
            rid = list(live)[rng.randint(len(live))]
            try:
                pool.fork(rid, next_id)
            except MemoryError:                        # no free slot
                _check_invariants(pool, live)
                continue
            live[next_id] = list(live[rid])
            clen[next_id] = clen[rid]
            next_id += 1
        elif op == 3 and live:                         # free (finish)
            rid = list(live)[rng.randint(len(live))]
            pool.free(rid)
            del live[rid], clen[rid]
        elif op == 4 and live:                         # truncate (spec
            rid = list(live)[rng.randint(len(live))]   # rollback)
            # engine contract: only the uncommitted tail is ever rolled
            # back (spec rejection truncates to the accepted cache_len,
            # which is >= the last committed block boundary)
            lo = max(clen[rid], 1)
            n = int(rng.randint(lo, len(live[rid]) + 1))
            pool.truncate(rid, n)
            live[rid] = live[rid][:n]
            # rolling back past a fork point must decref shared blocks,
            # never orphan them: conservation + exact refcounts below
            # catch both a leak and a double-free
            assert len(pool.table(rid)) == pool.blocks_for(n)
        _check_invariants(pool, live)
    for rid in list(live):
        pool.free(rid)
        del live[rid], clen[rid]
    _check_invariants(pool, live)
    # with everything freed, every block is free or cached
    assert pool.available_blocks == pool.usable_blocks


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_pool_invariants_hypothesis(model):
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def inner(seed):
        _run_trace(model, seed)
    inner()


@pytest.mark.parametrize("seed", range(8))
def test_pool_invariants_seeded(model, seed):
    """Seed-sweep fallback so the fuzz always runs, hypothesis or not."""
    _run_trace(model, seed)


def test_full_hit_after_commit(model):
    """Deterministic positive case: identical traffic re-uses every full
    block the first request committed (no eviction pressure)."""
    pool = _pool(model)
    toks = np.asarray([1, 0, 2, 1, 0, 2, 1], np.int32)
    assert pool.alloc(1, len(toks), tokens=toks) == 0
    pool.commit(1, toks)
    pool.free(1)
    assert pool.alloc(2, len(toks), tokens=toks) == 6  # 3 of 4 blocks (len-1)
    t2 = pool.table(2)
    assert len(t2) == pool.blocks_for(len(toks))


def test_intern_table_bounded(model):
    """Serving endless distinct traffic must not grow the prefix-intern
    table without bound: unreferenced ids are swept once the table passes
    its threshold, and ids are never reused after a sweep."""
    pool = _pool(model)
    rng = np.random.RandomState(0)
    for i in range(600):
        toks = rng.randint(0, 50, (8,))          # 4 blocks, ~all distinct
        pool.alloc(i, len(toks), tokens=toks)
        pool.commit(i, np.asarray(toks, np.int32))
        pool.free(i)
    # 600 requests x 4 distinct blocks >> the sweep threshold
    assert len(pool._intern) <= max(2 * 4 * NUM_BLOCKS, 256, 8 * NUM_BLOCKS)
    assert pool._next_pid >= len(pool._intern)   # ids monotonic, not reused
    _check_invariants(pool, {})


def test_cow_on_shared_partial_block(model):
    """extend() must copy a shared tail block before it is written."""
    pool = _pool(model)
    toks = np.asarray([0, 1, 2], np.int32)             # 2 blocks, 2nd partial
    pool.alloc(1, 3, tokens=toks)
    pool.commit(1, toks)
    pool.fork(1, 2)
    t1, t2 = pool.table(1), pool.table(2)
    assert t1 == t2 and pool.ref_count(t1[1]) == 2
    pool.extend(1, 4)                                  # write pos 3: shared!
    assert pool.stats["cow_copies"] == 1
    assert pool.table(1)[1] != pool.table(2)[1]        # tail diverged
    assert pool.table(1)[0] == pool.table(2)[0]        # full block shared
