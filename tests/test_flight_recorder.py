"""Flight recorder: bounded ring, ordered per-request history, postmortems.

Unit tests pin the ring mechanics (capacity bound, monotonic ``seq``,
``dropped`` accounting, strict-JSON bundles). The property test drives a
real ``ContinuousEngine`` over a deliberately tiny paged pool with random
staggered arrivals and forks — preemption, COW churn and (in the spec
variant) draft/verify rollback all happen for real — and asserts recorder
invariants under any interleaving:

  * the ring never exceeds its capacity, and ``seq`` + ``dropped`` account
    for every record ever made;
  * retained events are globally ordered by ``seq`` (so each request's
    history is order-preserved by construction);
  * each request's retained history is lifecycle-consistent: ``submit``
    precedes ``admit`` precedes ``first_token`` precedes ``finish``, at
    most one ``submit``/``finish``, and in any retained suffix admissions
    exceed preemptions by at most one;
  * with no drops, every finished request's history is *complete*: starts
    at ``submit``, ends at ``finish``, and carries exactly
    ``preemptions + 1`` admissions;
  * every event type stays inside the documented taxonomy.

With ``hypothesis`` installed the trace seeds are ``@given``-driven; a
fixed seed sweep keeps the fuzz in tier-1 regardless.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.obs import EVENT_TYPES, FlightRecorder
from repro.serve import ContinuousEngine

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

MAX_RUNNING = 3


@pytest.fixture(scope="module")
def smollm():
    cfg = get_smoke_config("smollm_135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def engine(smollm):
    """One engine for the whole sweep (compiles once); each trace swaps in
    a fresh recorder via ``_attach``."""
    cfg, model, params = smollm
    return ContinuousEngine(model, params, compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32, block_size=2,
                            num_blocks=14, max_running=MAX_RUNNING)


@pytest.fixture(scope="module")
def spec_engine(smollm):
    """Speculative variant: the target doubles as its own draft, so every
    verify round proposes, accepts, and rolls back rejected pages."""
    cfg, model, params = smollm
    return ContinuousEngine(model, params, compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32, block_size=2,
                            num_blocks=16, max_running=MAX_RUNNING,
                            draft_params=params, spec_k=2)


def _attach(eng, fl):
    eng.flight = fl
    eng.scheduler.flight = fl


# ------------------------------------------------------------- unit tests

class TestRingMechanics:
    def test_capacity_must_be_positive(self):
        with pytest.raises(ValueError):
            FlightRecorder(capacity=0)
        with pytest.raises(ValueError):
            FlightRecorder(capacity=-5)

    def test_bounded_with_drop_accounting(self):
        fl = FlightRecorder(capacity=16)
        for i in range(100):
            fl.record("submit", req_id=i)
        assert len(fl) == 16
        assert fl.dropped == 84
        seqs = [e["seq"] for e in fl.events()]
        assert seqs == list(range(84, 100))    # newest retained, gap visible

    def test_step_stamping(self):
        fl = FlightRecorder(capacity=8)
        fl.record("submit", req_id=1)
        fl.begin_step(7)
        fl.record("admit", req_id=1)
        steps = [e["step"] for e in fl.events()]
        assert steps == [-1, 7]                # -1 = before the first step

    def test_events_for_preserves_order(self):
        fl = FlightRecorder(capacity=32)
        for ev, rid in [("submit", 1), ("submit", 2), ("admit", 1),
                        ("admit", 2), ("finish", 1)]:
            fl.record(ev, req_id=rid)
        assert [e["event"] for e in fl.events_for(1)] == \
            ["submit", "admit", "finish"]
        assert [e["event"] for e in fl.events_for(2)] == ["submit", "admit"]

    def test_dump_is_strict_json(self, tmp_path):
        fl = FlightRecorder(capacity=8, dump_path=str(tmp_path / "pm.json"))
        fl.record("submit", req_id=0, ratio=float("inf"))
        out = fl.dump(reason="unit",
                      metrics={"bad": float("nan"), "ok": 1.5},
                      config={"dtype": jnp.float32})
        with open(out) as f:
            # parse_constant fires only on NaN/Infinity tokens: reject them
            bundle = json.load(
                f, parse_constant=lambda c: pytest.fail(f"non-strict {c}"))
        assert bundle["reason"] == "unit"
        assert bundle["metrics"] == {"bad": None, "ok": 1.5}
        assert bundle["events"][0]["ratio"] is None     # sanitized in-ring copy
        assert bundle["capacity"] == 8 and bundle["dropped"] == 0
        assert bundle["next_seq"] == 1


# --------------------------------------------------------- property tests

def _run_trace(cfg, eng, seed, n_requests=5):
    """Drive one randomized trace (staggered arrivals, forks, preemption
    churn from the tiny pool) and return (recorder, finished requests)."""
    rng = np.random.RandomState(seed)
    cap = 48 if seed % 2 else 4096     # odd seeds force ring wraparound
    fl = FlightRecorder(capacity=cap)
    _attach(eng, fl)
    try:
        pending = []
        arrive = 0
        for _ in range(n_requests):
            prompt = rng.randint(0, cfg.vocab_size,
                                 (rng.randint(2, 9),)).astype(np.int32)
            pending.append((arrive, prompt, int(rng.randint(2, 8))))
            arrive += int(rng.randint(0, 4))
        submitted, step = set(), 0
        while pending or eng.has_work():
            while pending and pending[0][0] <= step:
                _, prompt, nn = pending.pop(0)
                submitted.add(eng.submit(prompt, nn))
            eng.step()
            assert len(fl) <= cap
            running = list(eng.scheduler.running)
            if (running and rng.randint(4) == 0
                    and len(running) < MAX_RUNNING):
                parent = running[rng.randint(len(running))]
                try:
                    submitted.add(eng.fork(parent.req_id))
                except (ValueError, MemoryError):
                    pass               # slot/pool full: engine said no cleanly
            step += 1
            assert step < 2000, "trace failed to drain"
        fin = [r for r in eng.finished if r.req_id in submitted]
        assert len(fin) == len(submitted)
        return fl, fin
    finally:
        _attach(eng, None)


def _check_recorder(fl, fin):
    evs = fl.events()
    assert len(evs) <= fl.capacity
    seqs = [e["seq"] for e in evs]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    # seq + dropped account for every record ever made
    if evs:
        assert evs[-1]["seq"] + 1 - evs[0]["seq"] == len(evs)  # contiguous
        assert evs[0]["seq"] == fl.dropped
    for e in evs:
        assert e["event"] in EVENT_TYPES, e
        assert isinstance(e["step"], int)
    for r in fin:
        names = [e["event"] for e in fl.events_for(r.req_id)]
        assert names.count("submit") <= 1 and names.count("finish") <= 1
        for a, b in [("submit", "admit"), ("admit", "first_token"),
                     ("first_token", "finish")]:
            if a in names and b in names:
                assert names.index(a) < names.index(b), (r.req_id, names)
        # per-request shape: submit, admit, (preempt, admit)*, ..., finish —
        # any retained suffix has at most one more admit than preempt
        assert names.count("admit") <= names.count("preempt") + 1, names
        if fl.dropped == 0 and names:
            # complete history: full lifecycle, exact re-admission count.
            # A forked child's history starts at the fork (it is adopted
            # into the running set directly, never queued) and it inherits
            # the parent's first-token timestamp, so it only re-admits
            # after preemptions.
            if "fork" in names:
                assert names[0] == "fork" and names[-1] == "finish"
                assert names.count("admit") == r.preemptions, (r.req_id,
                                                               names)
            else:
                assert names[0] == "submit" and names[-1] == "finish"
                assert "first_token" in names
                assert names.count("admit") == r.preemptions + 1, (r.req_id,
                                                                   names)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_recorder_invariants_hypothesis(smollm, engine):
    cfg, _, _ = smollm

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def inner(seed):
        fl, fin = _run_trace(cfg, engine, seed)
        _check_recorder(fl, fin)
    inner()


@pytest.mark.parametrize("seed", range(4))
def test_recorder_invariants_seeded(smollm, engine, seed):
    """Seed-sweep fallback so the fuzz always runs, hypothesis or not."""
    cfg, _, _ = smollm
    fl, fin = _run_trace(cfg, engine, seed)
    _check_recorder(fl, fin)


def test_recorder_invariants_speculative(smollm, spec_engine):
    """Spec lane: rollback truncations interleave with preemption and fork;
    the recorder additionally carries per-round proposed/accepted counts
    that must reconcile with the request's own totals when nothing
    dropped."""
    cfg, _, _ = smollm
    fl, fin = _run_trace(cfg, spec_engine, seed=2)
    _check_recorder(fl, fin)
    assert any(e["event"] == "spec_round" for e in fl.events())
    if fl.dropped == 0:
        for r in fin:
            rounds = [e for e in fl.events_for(r.req_id)
                      if e["event"] == "spec_round"]
            assert sum(e["proposed"] for e in rounds) == r.spec_proposed
            assert sum(e["accepted"] for e in rounds) == r.spec_accepted
