"""Serving engine: generation shapes, determinism, compressed-model serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CompressConfig
from repro.configs import get_smoke_config
from repro.core.calibrate import calibrate_model
from repro.core.compress import compress_model
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.serve import ServeEngine


def _engine(arch="smollm_135m", params=None, dtype=jnp.float32):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    if params is None:
        params = model.init(jax.random.PRNGKey(0))
    return cfg, model, ServeEngine(model, params, compute_dtype=dtype,
                                   cache_dtype=dtype)


def test_generate_shapes_and_determinism():
    cfg, model, eng = _engine()
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    out1 = eng.generate(prompt, max_new_tokens=6)
    out2 = eng.generate(prompt, max_new_tokens=6)
    assert out1.shape == (2, 14)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1[:, :8]), np.asarray(prompt))


def test_generate_matches_teacher_forcing():
    """Greedy generation step i must equal argmax of a fresh prefill over
    the generated prefix (KV-cache correctness end-to-end)."""
    cfg, model, eng = _engine()
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 5), 0,
                                cfg.vocab_size)
    out = eng.generate(prompt, max_new_tokens=4)
    for i in range(4):
        prefix = out[:, :5 + i]
        cache = model.init_cache(1, 32, dtype=jnp.float32)
        logits, _ = model.prefill(eng.params, prefix, cache,
                                  compute_dtype=jnp.float32)
        want = int(jnp.argmax(logits[:, -1], axis=-1)[0])
        assert int(out[0, 5 + i]) == want, f"mismatch at generated pos {i}"


def test_temperature_sampling_varies_with_seed():
    cfg, model, eng = _engine()
    prompt = jnp.zeros((1, 4), jnp.int32)
    a = eng.generate(prompt, max_new_tokens=8, temperature=1.5, seed=0)
    b = eng.generate(prompt, max_new_tokens=8, temperature=1.5, seed=1)
    assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_serve_compressed_model():
    """COALA-compressed params plug straight into the engine."""
    cfg, model, eng = _engine("llama3_1b")
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=2), cfg)
    cal = calibrate_model(model, eng.params, [pipe.get_batch(i)
                                              for i in range(2)])
    cparams, reports = compress_model(model, eng.params, cal,
                                      CompressConfig(method="coala",
                                                     ratio=0.6, lam=4.0))
    assert reports, "nothing compressed"
    _, _, ceng = _engine("llama3_1b", params=cparams)
    prompt = jnp.ones((2, 4), jnp.int32)
    out = ceng.generate(prompt, max_new_tokens=5)
    assert out.shape == (2, 9)
    assert np.all(np.asarray(out) >= 0)


def test_whisper_generate():
    cfg, model, _ = _engine("whisper_base")
    params = model.init(jax.random.PRNGKey(3))
    eng = ServeEngine(model, params, compute_dtype=jnp.float32,
                      cache_dtype=jnp.float32)
    frames = jax.random.normal(jax.random.PRNGKey(4),
                               (2, cfg.n_audio_frames, cfg.d_model))
    prompt = jnp.ones((2, 3), jnp.int32)
    out = eng.generate(prompt, max_new_tokens=4, extras={"frames": frames})
    assert out.shape == (2, 7)
