"""Chunked-prefill kernel vs oracles (interpret=True on CPU).

Kernel level: three-way parity between the Pallas kernel (interpret mode —
the exact program Mosaic would lower on TPU), the ``jax.nn`` reference
fallback, and a dense fp64 oracle that materializes each row's contiguous
prefix+suffix KV — across GQA/window/softcap, ragged suffix lengths,
prefix-offset causal masks, zero-length rows, and trash-page padding.

Engine level: three-way greedy token parity (chunked-prefill kernel vs the
gather oracle vs the legacy fixed-batch ``ServeEngine``) under a staggered
shared-prefix trace, including with the interpret-mode kernels forced into
the engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.chunked_prefill import chunked_prefill, chunked_prefill_ref


def _case(key, *, b, hq, hkv, hd, bs, num_blocks, starts, lens, lq=None):
    """Random pages + tables covering each row's prefix+suffix tokens.

    Pages already hold both the cached-prefix KV and the new suffix KV
    (in the serving path ``models/attention.py`` scatters the suffix in
    before the kernel runs — the kernel itself only reads pages)."""
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    lq = lq or max(max(lens), 1)
    q = jax.random.normal(ks[0], (b, lq, hq, hd), jnp.float32)
    k_pages = jax.random.normal(ks[1], (num_blocks, bs, hkv, hd), jnp.float32)
    v_pages = jax.random.normal(ks[2], (num_blocks, bs, hkv, hd), jnp.float32)
    totals = [s + l for s, l in zip(starts, lens)]
    nb = max(max(-(-t // bs) for t in totals), 1)
    tables = np.zeros((b, nb), np.int32)
    nxt = 1
    for i, t in enumerate(totals):
        for j in range(-(-t // bs)):
            tables[i, j] = nxt
            nxt += 1
    assert nxt <= num_blocks, "test pool too small"
    return (q, k_pages, v_pages, jnp.asarray(tables),
            jnp.asarray(starts, jnp.int32), jnp.asarray(lens, jnp.int32))


def _dense_oracle(q, k_pages, v_pages, tables, starts, lens, *, scale=None,
                  cap=0.0, window=0):
    """Per-row, per-query contiguous softmax attention in fp64; query j of
    row i sits at global position starts[i] + j and attends [0, that]."""
    q = np.asarray(q, np.float64)
    kp = np.asarray(k_pages, np.float64)
    vp = np.asarray(v_pages, np.float64)
    tables, starts, lens = map(np.asarray, (tables, starts, lens))
    b, lq, hq, hd = q.shape
    bs, hkv = kp.shape[1], kp.shape[2]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    out = np.zeros_like(q)
    for i in range(b):
        total = int(starts[i] + lens[i])
        k = kp[tables[i]].reshape(-1, hkv, hd)[:total]
        v = vp[tables[i]].reshape(-1, hkv, hd)[:total]
        for j in range(int(lens[i])):
            iq = int(starts[i]) + j
            lo = max(0, iq + 1 - window) if window > 0 else 0
            for h in range(hq):
                s = (k[lo:iq + 1, h // g] @ q[i, j, h]) * scale
                if cap > 0:
                    s = cap * np.tanh(s / cap)
                p = np.exp(s - s.max())
                p /= p.sum()
                out[i, j, h] = p @ v[lo:iq + 1, h // g]
    return out


CASES = [
    # (hq, hkv, starts, lens, bs, cap, window)
    (4, 2, [0, 8, 4], [5, 7, 1], 4, 0.0, 0),     # GQA, ragged, prefix offsets
    (3, 1, [12, 0], [3, 9], 4, 0.0, 0),          # MQA-style sharing (g=3)
    (2, 2, [8, 0, 16], [8, 2, 5], 8, 0.0, 0),    # MHA, bs=8, block-aligned
    (4, 2, [8, 4], [6, 9], 4, 50.0, 0),          # logit softcap (gemma2)
    (4, 2, [16, 0, 8], [5, 11, 3], 4, 0.0, 6),   # sliding window over prefix
    (4, 2, [12, 4], [7, 2], 4, 30.0, 5),         # window + cap together
]


@pytest.mark.parametrize("hq,hkv,starts,lens,bs,cap,window", CASES)
def test_kernel_matches_dense_oracle(hq, hkv, starts, lens, bs, cap, window):
    q, kp, vp, tables, st, ln = _case(0, b=len(starts), hq=hq, hkv=hkv,
                                      hd=16, bs=bs, num_blocks=24,
                                      starts=starts, lens=lens)
    want = _dense_oracle(q, kp, vp, tables, st, ln, cap=cap, window=window)
    got = chunked_prefill(q, kp, vp, tables, st, ln, cap=cap, window=window,
                          block_q=4, interpret=True)
    got_ref = chunked_prefill_ref(q, kp, vp, tables, st, ln, cap=cap,
                                  window=window)
    for i, l in enumerate(lens):
        np.testing.assert_allclose(np.asarray(got)[i, :l], want[i, :l],
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(got_ref)[i, :l], want[i, :l],
                                   rtol=2e-5, atol=2e-5)
        # padded query rows (bucket padding past lens) are exactly zero
        np.testing.assert_array_equal(np.asarray(got)[i, l:], 0.0)
        np.testing.assert_array_equal(np.asarray(got_ref)[i, l:], 0.0)


def test_zero_length_rows_are_zero_and_finite():
    """Batch-padding rows (lens 0, all-trash table) must not NaN — even
    with a nonzero start pointing at a cached prefix."""
    q, kp, vp, tables, st, ln = _case(1, b=3, hq=4, hkv=2, hd=8, bs=4,
                                      num_blocks=12,
                                      starts=[4, 0, 8], lens=[6, 0, 0])
    for fn in (lambda: chunked_prefill(q, kp, vp, tables, st, ln,
                                       block_q=4, interpret=True),
               lambda: chunked_prefill_ref(q, kp, vp, tables, st, ln)):
        out = np.asarray(fn())
        assert np.all(np.isfinite(out))
        np.testing.assert_array_equal(out[1:], 0.0)


def test_trash_page_padding_is_ignored():
    """Ragged table padding points at page 0; poisoning it must not change
    any valid output."""
    q, kp, vp, tables, st, ln = _case(2, b=2, hq=2, hkv=1, hd=8, bs=4,
                                      num_blocks=12,
                                      starts=[0, 8], lens=[3, 6])
    kp2 = kp.at[0].set(1e4)
    vp2 = vp.at[0].set(1e4)
    a = chunked_prefill(q, kp, vp, tables, st, ln, block_q=4, interpret=True)
    bb = chunked_prefill(q, kp2, vp2, tables, st, ln, block_q=4,
                         interpret=True)
    for i, l in enumerate(np.asarray(ln)):
        np.testing.assert_allclose(np.asarray(a)[i, :l],
                                   np.asarray(bb)[i, :l], rtol=1e-6)


def test_query_chunking_invariant():
    """block_q only tiles the grid; outputs must not depend on it."""
    q, kp, vp, tables, st, ln = _case(3, b=2, hq=4, hkv=2, hd=8, bs=4,
                                      num_blocks=16,
                                      starts=[4, 0], lens=[9, 13])
    outs = [np.asarray(chunked_prefill(q, kp, vp, tables, st, ln,
                                       block_q=bq, interpret=True))
            for bq in (2, 4, 16)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-5, atol=2e-5)


def test_ops_dispatch_ref_on_cpu():
    """ops.chunked_prefill auto-routes to the jax.nn fallback off-TPU."""
    q, kp, vp, tables, st, ln = _case(4, b=2, hq=4, hkv=2, hd=8, bs=4,
                                      num_blocks=12,
                                      starts=[4, 0], lens=[5, 9])
    auto = ops.chunked_prefill(q, kp, vp, tables, st, ln)
    ref = chunked_prefill_ref(q, kp, vp, tables, st, ln)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(ref))


@pytest.mark.slow
@pytest.mark.parametrize("hd", [16, 64])
def test_kernel_large_sweep(hd):
    q, kp, vp, tables, st, ln = _case(5, b=4, hq=4, hkv=2, hd=hd, bs=8,
                                      num_blocks=32,
                                      starts=[24, 0, 8, 16],
                                      lens=[17, 31, 1, 9])
    want = _dense_oracle(q, kp, vp, tables, st, ln)
    got = chunked_prefill(q, kp, vp, tables, st, ln, block_q=8,
                          interpret=True)
    for i, l in enumerate([17, 31, 1, 9]):
        np.testing.assert_allclose(np.asarray(got)[i, :l], want[i, :l],
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Engine level: kernel prefill vs gather oracle vs fixed-batch oracle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smollm():
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("smollm_135m")
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _cont(model, params, **kw):
    from repro.serve import ContinuousEngine
    kw.setdefault("block_size", 4)
    kw.setdefault("num_blocks", 64)
    kw.setdefault("max_running", 4)
    return ContinuousEngine(model, params, compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32, **kw)


def _oracle_tokens(model, params, prompt, n):
    from repro.serve import ServeEngine
    leg = ServeEngine(model, params, compute_dtype=jnp.float32,
                      cache_dtype=jnp.float32)
    return np.asarray(leg.generate(jnp.asarray(prompt)[None],
                                   max_new_tokens=n))[0, len(prompt):]


def _staggered(eng, prompts, news):
    ids = []
    for p, n in zip(prompts, news):
        ids.append(eng.submit(p, n))
        eng.step()                          # join mid-decode
    eng.run()
    fin = {r.req_id: r for r in eng.finished}
    return [np.asarray(fin[i].out_tokens) for i in ids]


def _shared_prefix_prompts(cfg, rng, *, prefix_len, tails):
    shared = rng.randint(0, cfg.vocab_size, (prefix_len,)).astype(np.int32)
    return [np.concatenate(
        [shared, rng.randint(0, cfg.vocab_size, (t,)).astype(np.int32)])
        for t in tails]


def test_engine_prefill_kernel_on_by_default(smollm):
    _, model, params = smollm
    eng = _cont(model, params)
    assert eng.prefill_kernel            # auto-on for pure-attention GQA LMs


def test_engine_parity_shared_prefix_staggered(smollm):
    """Three-way greedy token parity under the staggered shared-prefix
    trace: chunked-prefill kernel path vs the gather oracle vs the
    fixed-batch ServeEngine — with prefix hits, so suffix prefills run at
    nonzero cache offsets."""
    cfg, model, params = smollm
    rng = np.random.RandomState(0)
    prompts = _shared_prefix_prompts(cfg, rng, prefix_len=12, tails=(3, 5, 7))
    prompts.append(rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32))
    news = [5, 5, 4, 5]
    ek = _cont(model, params, prefill_kernel=True)
    out_k = _staggered(ek, prompts, news)
    eg = _cont(model, params, prefill_kernel=False)
    out_g = _staggered(eg, prompts, news)
    assert ek.metrics()["prefix_hit_tokens"] >= 2 * 12
    assert ek.metrics()["prefill_kernel"] == 1.0
    assert eg.metrics()["prefill_kernel"] == 0.0
    for p, n, gk, gg in zip(prompts, news, out_k, out_g):
        ref = _oracle_tokens(model, params, p, n)
        np.testing.assert_array_equal(ref, gk,
                                      err_msg="kernel prefill diverged")
        np.testing.assert_array_equal(ref, gg,
                                      err_msg="gather prefill diverged")


def test_engine_parity_interpret_kernel(smollm):
    """The interpret-mode Pallas kernels (decode + chunked prefill) forced
    into the engine stay on the oracle trajectory — short trace, the CI
    stand-in for native-TPU execution."""
    cfg, model, params = smollm
    rng = np.random.RandomState(1)
    prompts = _shared_prefix_prompts(cfg, rng, prefix_len=8, tails=(2, 5))
    eng = _cont(model, params, prefill_kernel=True, paged_kernel=True,
                paged_attn_impl="pallas")
    out = _staggered(eng, prompts, [4, 4])
    for p, got in zip(prompts, out):
        np.testing.assert_array_equal(_oracle_tokens(model, params, p, 4),
                                      got)


def test_prefill_kernel_rejected_for_unsupported_model():
    """Recurrent/hybrid archs cannot ride the chunked paged path."""
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("xlstm_1_3b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = _cont(model, params, prefix_cache=False)
    assert not eng.prefill_kernel
    with pytest.raises(ValueError):
        _cont(model, params, prefix_cache=False, prefill_kernel=True)


@pytest.mark.slow
def test_engine_parity_gemma2_window_softcap():
    """gemma2 local/global windows + logit softcaps through the kernel
    prefill path on a staggered shared-prefix trace."""
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("gemma2_27b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(2)
    prompts = _shared_prefix_prompts(cfg, rng, prefix_len=10, tails=(3, 6, 2))
    eng = _cont(model, params, prefill_kernel=True,
                paged_attn_impl="pallas")
    out = _staggered(eng, prompts, [5, 5, 5])
    for p, got in zip(prompts, out):
        np.testing.assert_array_equal(_oracle_tokens(model, params, p, 5),
                                      got)
