"""Adapter initialization (paper §6.2): exactness + trainability invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.adapters import init_adapters, mask_grads, merge_adapters
from repro.core.calibrate import calibrate_model
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.models.linear import linear_apply


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3_1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=2), cfg)
    cal = calibrate_model(model, params, [pipe.get_batch(i) for i in range(2)])
    return cfg, model, params, cal


@pytest.mark.parametrize("method", ["pissa", "coala_a1", "coala_a2"])
def test_merge_recovers_original(setup, method):
    """W_res + A·B == W exactly for subspace-projection inits."""
    cfg, model, params, cal = setup
    new_params, mask = init_adapters(params, cal.r_factors(), method=method,
                                     rank=4)
    merged = merge_adapters(new_params)

    def collect_ws(tree, out):
        if isinstance(tree, dict):
            if "w" in tree and getattr(tree["w"], "ndim", 0) == 2:
                out.append(tree["w"])
            else:
                for v in tree.values():
                    collect_ws(v, out)
        elif isinstance(tree, list):
            for v in tree:
                collect_ws(v, out)

    orig, back = [], []
    collect_ws(params, orig)
    collect_ws(merged, back)
    assert len(orig) == len(back)
    for a, b in zip(orig, back):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_lora_init_preserves_forward(setup):
    """LoRA starts with B=0, so the adapted model == the base model."""
    cfg, model, params, cal = setup
    new_params, _ = init_adapters(params, cal.r_factors(), method="lora",
                                  rank=4)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                    global_batch=2), cfg)
    batch = pipe.get_batch(0)
    l0, _ = model.loss(params, batch, compute_dtype=jnp.float32)
    l1, _ = model.loss(new_params, batch, compute_dtype=jnp.float32)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_adapter_forward_math():
    """{"w", "b_t", "a_t"} linear == dense + low-rank sum."""
    k = jax.random.PRNGKey(0)
    w = jax.random.normal(k, (8, 6))
    b_t = jax.random.normal(jax.random.fold_in(k, 1), (8, 2))
    a_t = jax.random.normal(jax.random.fold_in(k, 2), (2, 6))
    x = jax.random.normal(jax.random.fold_in(k, 3), (4, 8))
    got = linear_apply({"w": w, "b_t": b_t, "a_t": a_t}, x)
    want = x @ w + (x @ b_t) @ a_t
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_grad_mask_freezes_base(setup):
    cfg, model, params, cal = setup
    new_params, mask = init_adapters(params, cal.r_factors(),
                                     method="coala_a1", rank=4)
    grads = jax.tree.map(jnp.ones_like, new_params)
    masked = mask_grads(grads, mask)
    flat = jax.tree_util.tree_flatten_with_path(masked)[0]
    saw_adapter = saw_frozen = False
    for path, leaf in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        if keys[-1] in ("b_t", "a_t"):
            assert float(jnp.abs(leaf).max()) == 1.0
            saw_adapter = True
        elif keys[-1] == "w" and leaf.ndim >= 2:
            if float(jnp.abs(leaf).max()) == 0.0:
                saw_frozen = True
    assert saw_adapter and saw_frozen
