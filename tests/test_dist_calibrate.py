"""Sharded Gram-free calibration (repro.dist.calibrate) on fake meshes.

Contracts under test (subprocess: jax locks device count at init, so each
scenario runs in its own interpreter with 8 fake host devices):

  * shard-count invariance — per-layer R factors from ``calibrate_sharded``
    on 1, 4 and 8 data shards all match, and match the single-device
    ``Calibrator`` output, within fp32 tolerance (R is unique for full-rank
    X under the non-negative-diagonal sign convention);
  * the on-mesh butterfly reduce equals the serial TSQR tree;
  * numerical stability survives the distributed reduction — the sharded
    QR path stays near the fp64 oracle on ill-conditioned calibration data
    while the (equally distributed) Gram accumulation path degrades, the
    mesh-scale mirror of test_coala's
    ``test_qr_path_beats_gram_paths_when_ill_conditioned``.
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_shard_count_invariance_and_single_device_parity():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.core.calibrate import calibrate_model
        from repro.core.tsqr import qr_r, square_r, tsqr_tree
        from repro.data import DataConfig, TokenPipeline
        from repro.dist.calibrate import calibrate_sharded, combine_r_shards
        cfg = get_smoke_config("smollm_135m")
        from repro.models import build_model
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                        global_batch=8, seed=3), cfg)
        batches = [pipe.get_batch(i) for i in range(2)]
        single = calibrate_model(model, params, batches).r_factors()
        assert single, "no layers calibrated"
        meshes = {n: jax.make_mesh((n,), ("data",),
                                   devices=jax.devices()[:n],
                                   axis_types=(jax.sharding.AxisType.Auto,))
                  for n in (1, 4, 8)}
        results = {n: calibrate_sharded(model, params, batches, m).r_factors()
                   for n, m in meshes.items()}
        for n, rf in results.items():
            assert set(rf) == set(single), (n, sorted(rf), sorted(single))
        # R is unique up to a left-orthogonal factor whose entrywise effect
        # grows with cond(X): compare entrywise where X is well-conditioned,
        # and always as the quadratic form R^T R (the object COALA's
        # weighted projection is invariant under — W R'^T = W R^T Q^T shares
        # singular structure with W R^T for any orthogonal Q)
        worst = None
        for path, ref in single.items():
            ref = np.asarray(ref)
            sv = np.linalg.svd(ref, compute_uv=False)
            cond = sv[0] / max(sv[-1], 1e-30)
            if worst is None or cond > worst[1]:
                worst = (path, cond)
            gram_ref = ref.T @ ref
            for n, rf in results.items():
                got = np.asarray(rf[path])
                grel = np.linalg.norm(got.T @ got - gram_ref) \\
                    / np.linalg.norm(gram_ref)
                assert grel <= 2e-3, (path, n, grel)
                if cond < 1e5:
                    tol = 5e-3 * max(1.0, float(np.abs(ref).max()))
                    err = float(np.abs(got - ref).max())
                    assert err <= tol, (path, n, err, tol)
        # the ill-conditioned layer: downstream COALA projections agree even
        # though R itself is only defined up to the orthogonal factor
        from repro.core.coala import coala_project
        path, _ = worst
        w = jax.random.normal(jax.random.PRNGKey(9),
                              (24, single[path].shape[0]), jnp.float32)
        ref_proj = np.asarray(coala_project(w, r_factor=single[path], rank=6))
        for n, rf in results.items():
            got_proj = np.asarray(coala_project(w, r_factor=rf[path], rank=6))
            rel = np.linalg.norm(got_proj - ref_proj) \\
                / np.linalg.norm(ref_proj)
            assert rel <= 2e-3, (path, n, rel)

        # butterfly reduce == serial TSQR tree on raw random chunks
        chunks = [jax.random.normal(jax.random.PRNGKey(10 + i), (40, 16))
                  for i in range(8)]
        r_serial = square_r(tsqr_tree(chunks))
        r_stack = jnp.stack([square_r(qr_r(c)) for c in chunks])
        r_bfly = combine_r_shards(r_stack, meshes[8], axis="data")
        np.testing.assert_allclose(np.asarray(r_bfly), np.asarray(r_serial),
                                   rtol=2e-4, atol=2e-4)
        print("OK")
    """)


def test_sharded_qr_beats_gram_when_ill_conditioned():
    # cond pinned at 1e9 (as in test_coala): Gram conditioning is 1e18 >>
    # 1/eps32, so the distributed Gram sum degrades on every BLAS while the
    # per-shard QR + butterfly reduce stays near the fp64 oracle
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import baselines
        from repro.core.coala import coala_project
        from repro.core.tsqr import qr_r, square_r
        from repro.dist.calibrate import combine_r_shards, split_batch
        n, k, rank, cond, shards = 32, 512, 6, 1e9, 8
        def rand(a, b, key):
            return jax.random.normal(jax.random.PRNGKey(key), (a, b),
                                     jnp.float32)
        u = jnp.linalg.qr(rand(n, n, 30))[0]
        v = jnp.linalg.qr(rand(k, n, 31))[0]
        s = jnp.logspace(0, -np.log10(cond), n).astype(jnp.float32)
        x = (u * s[None, :]) @ v.T                       # X: (n, k)
        w = rand(24, n, 32)

        # fp64 ground truth
        w64, x64 = np.asarray(w, np.float64), np.asarray(x, np.float64)
        uu = np.linalg.svd(w64 @ x64)[0][:, :rank]
        w_ref = uu @ uu.T @ w64
        def rel(w_apx):
            return np.linalg.norm(np.asarray(w_apx, np.float64) - w_ref, 2) \\
                / np.linalg.norm(w_ref, 2)

        # shard the token rows of X^T; per-shard local R, butterfly reduce
        xt_shards = [x.T[i * (k // shards):(i + 1) * (k // shards)]
                     for i in range(shards)]
        mesh = jax.make_mesh((shards,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        r_stack = jnp.stack([square_r(qr_r(xs)) for xs in xt_shards])
        r_dist = combine_r_shards(r_stack, mesh, axis="data")
        coala_err = rel(coala_project(w, r_factor=r_dist, rank=rank))

        # the distributed Gram path: per-shard Gram partials, summed
        gram = sum(xs.T @ xs for xs in xt_shards)
        a, b = baselines.svd_llm_v2(w, gram, rank)
        v2_err = rel(a @ b)

        assert coala_err < 1e-2, coala_err
        assert not np.isfinite(v2_err) or v2_err > 10 * coala_err, \\
            (coala_err, v2_err)
        print("OK")
    """)
