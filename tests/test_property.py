"""Property-based tests (hypothesis) on the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import coala_project, eym_truncate, r_from_x, weighted_error
from repro.core import baselines, theory, tsqr

SET = dict(max_examples=15, deadline=None)


def _arrays(seed, m, n, k):
    kk = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(kk)
    w = jax.random.normal(k1, (m, n), jnp.float32)
    x = jax.random.normal(k2, (n, k), jnp.float32)
    return w, x


@settings(**SET)
@given(seed=st.integers(0, 10_000), m=st.integers(4, 24), n=st.integers(4, 24),
       k=st.integers(2, 48), r=st.integers(1, 8))
def test_coala_attains_theoretical_optimum(seed, m, n, k, r):
    w, x = _arrays(seed, m, n, k)
    r = min(r, m, n)
    err = float(weighted_error(w, coala_project(w, x, rank=r), x))
    opt = float(theory.optimal_weighted_error(w, x, r))
    assert err <= opt * (1 + 1e-3) + 1e-4


@settings(**SET)
@given(seed=st.integers(0, 10_000), m=st.integers(6, 20), n=st.integers(6, 20),
       k=st.integers(6, 40))
def test_error_monotone_in_rank(seed, m, n, k):
    w, x = _arrays(seed, m, n, k)
    errs = [float(weighted_error(w, coala_project(w, x, rank=r), x))
            for r in (1, 2, 4, min(m, n))]
    assert all(a >= b - 1e-4 for a, b in zip(errs, errs[1:]))


@settings(**SET)
@given(seed=st.integers(0, 10_000), m=st.integers(6, 20), n=st.integers(6, 20),
       k=st.integers(6, 40), r=st.integers(1, 6))
def test_coala_never_worse_than_plain_svd(seed, m, n, k, r):
    w, x = _arrays(seed, m, n, k)
    r = min(r, m, n)
    e_coala = float(weighted_error(w, coala_project(w, x, rank=r), x))
    a, b = baselines.plain_svd(w, r)
    e_svd = float(weighted_error(w, a @ b, x))
    assert e_coala <= e_svd * (1 + 1e-3) + 1e-4


@settings(**SET)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 24),
       k=st.integers(4, 200), chunks=st.integers(1, 7))
def test_tsqr_rtr_invariant(seed, n, k, chunks):
    """RᵀR == XXᵀ regardless of how the token stream is chunked."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, k), jnp.float32)
    xt = x.T
    bounds = np.linspace(0, k, chunks + 1).astype(int)
    parts = [xt[a:b] for a, b in zip(bounds, bounds[1:]) if b > a]
    r = tsqr.tsqr_sequential(parts)
    np.testing.assert_allclose(np.asarray(r.T @ r), np.asarray(x @ x.T),
                               rtol=5e-3, atol=5e-3)


@settings(**SET)
@given(seed=st.integers(0, 10_000), m=st.integers(6, 16), n=st.integers(6, 16),
       k=st.integers(2, 10), r=st.integers(1, 4))
def test_projector_idempotent(seed, m, n, k, r):
    """W'' from re-compressing W' equals W' (projection property)."""
    w, x = _arrays(seed, m, n, k)
    r = min(r, m, n)
    w1 = coala_project(w, x, rank=r)
    w2 = coala_project(w1, x, rank=r)
    scale = float(jnp.linalg.norm(w1)) + 1e-6
    assert float(jnp.linalg.norm(w1 - w2)) <= 5e-3 * scale


@settings(**SET)
@given(seed=st.integers(0, 10_000), m=st.integers(8, 16), n=st.integers(8, 16),
       k=st.integers(3, 6), r=st.integers(1, 4))
def test_regularization_shrinks_toward_w(seed, m, n, k, r):
    """As μ → ∞ the solution approaches the unweighted EYM of W."""
    w, x = _arrays(seed, m, n, k)
    r = min(r, m, n)
    w_big_mu = coala_project(w, x, rank=r, mu=1e6)
    eym = eym_truncate(w, r)
    scale = float(jnp.linalg.norm(eym)) + 1e-6
    assert float(jnp.linalg.norm(w_big_mu - eym)) <= 1e-2 * scale


@settings(**SET)
@given(seed=st.integers(0, 10_000))
def test_quantization_roundtrip_bounded(seed):
    from repro.train.grad_compress import simulate_roundtrip
    g = jax.random.normal(jax.random.PRNGKey(seed), (513,)) * \
        (10.0 ** ((seed % 7) - 3))
    rt = simulate_roundtrip(g)
    rel = float(jnp.linalg.norm(g - rt) / (jnp.linalg.norm(g) + 1e-30))
    assert rel < 0.02
