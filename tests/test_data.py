"""Data pipeline: determinism, exact resume, learnability structure."""
import jax
import numpy as np

from repro.data import DataConfig, TokenPipeline, calibration_stream


def test_deterministic_per_step():
    d = DataConfig(vocab_size=64, seq_len=32, global_batch=4, seed=5)
    a = TokenPipeline(d).get_batch(17)["tokens"]
    b = TokenPipeline(d).get_batch(17)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_different_steps_differ():
    d = DataConfig(vocab_size=64, seq_len=32, global_batch=4)
    a = TokenPipeline(d).get_batch(0)["tokens"]
    b = TokenPipeline(d).get_batch(1)["tokens"]
    assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_resume_equals_continuous():
    d = DataConfig(vocab_size=64, seq_len=16, global_batch=2)
    pipe = TokenPipeline(d)
    continuous = [pipe.get_batch(s)["tokens"] for s in range(10)]
    resumed = [TokenPipeline(d).get_batch(s)["tokens"] for s in range(5, 10)]
    for a, b in zip(continuous[5:], resumed):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mostly_predictable_structure():
    """~(1-noise) of transitions follow the affine map — learnable signal."""
    d = DataConfig(vocab_size=97, seq_len=128, global_batch=8, noise=0.15)
    toks = np.asarray(TokenPipeline(d).get_batch(3)["tokens"])
    hits = 0,
    total = 0
    hit = 0
    for row in toks:
        for t in range(len(row) - 1):
            # offset varies per stream in [0,7)
            if any((row[t] * 3 + 7 + o) % 97 == row[t + 1] for o in range(7)):
                hit += 1
            total += 1
    assert hit / total > 0.7, hit / total


def test_calibration_stream_disjoint_and_deterministic():
    d = DataConfig(vocab_size=64, seq_len=16, global_batch=2)
    c1 = [b["tokens"] for b in calibration_stream(d, 3)]
    c2 = [b["tokens"] for b in calibration_stream(d, 3)]
    for a, b in zip(c1, c2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    train0 = TokenPipeline(d).get_batch(0)["tokens"]
    assert not np.array_equal(np.asarray(c1[0]), np.asarray(train0))
