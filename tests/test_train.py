"""Training loop: convergence, grad-accum equivalence, schedules, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.configs import get_smoke_config
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.models.common import CPU_CTX
from repro.train.optimizer import lr_at, clip_by_global_norm, global_norm
from repro.train.train_loop import make_train_state, make_train_step
from repro.train import grad_compress as gc


def test_loss_decreases_on_synthetic_lm():
    cfg = get_smoke_config("smollm_135m")
    model = build_model(cfg)
    # tokens drawn from an effective vocab of 64 (< cfg.vocab_size): the model
    # reaches well under the uniform baseline within ~100 steps on CPU
    dcfg = DataConfig(vocab_size=64, seq_len=64, global_batch=8, seed=3)
    pipe = TokenPipeline(dcfg, cfg)
    tcfg = TrainConfig(lr=5e-3, warmup_steps=5, total_steps=100,
                       schedule="cosine", compute_dtype="float32")
    state = make_train_state(model, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tcfg, ctx=CPU_CTX))
    first = None
    for i in range(100):
        state, metrics = step(state, pipe.get_batch(i))
        if i == 0:
            first = float(metrics["ce"])
    last = float(metrics["ce"])
    uniform = np.log(cfg.vocab_size)
    assert first == pytest.approx(np.log(cfg.vocab_size), rel=0.25)
    assert last < uniform - 0.8, (first, last, uniform)


def test_grad_accum_equivalence():
    cfg = get_smoke_config("olmo_1b")
    model = build_model(cfg)
    tcfg1 = TrainConfig(microbatches=1, compute_dtype="float32")
    tcfg2 = TrainConfig(microbatches=2, compute_dtype="float32")
    state = make_train_state(model, tcfg1, jax.random.PRNGKey(1))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 32),
                                          0, cfg.vocab_size)}
    s1, m1 = jax.jit(make_train_step(model, tcfg1, CPU_CTX))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, tcfg2, CPU_CTX))(state, batch)
    np.testing.assert_allclose(float(m1["ce"]), float(m2["ce"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


class TestSchedules:
    def test_wsd_shape(self):
        tcfg = TrainConfig(lr=1.0, warmup_steps=10, total_steps=100,
                           schedule="wsd", decay_frac=0.2)
        assert float(lr_at(tcfg, 0)) < 0.2            # warmup start
        assert float(lr_at(tcfg, 9)) == pytest.approx(1.0)
        assert float(lr_at(tcfg, 50)) == pytest.approx(1.0)   # stable
        assert float(lr_at(tcfg, 99)) < 0.2           # decayed
        # monotone decay in the tail
        tail = [float(lr_at(tcfg, s)) for s in range(80, 100, 4)]
        assert all(a >= b for a, b in zip(tail, tail[1:]))

    def test_cosine_endpoints(self):
        tcfg = TrainConfig(lr=1.0, warmup_steps=0, total_steps=100,
                           schedule="cosine")
        assert float(lr_at(tcfg, 99)) < 0.01

    def test_clip(self):
        tree = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) == pytest.approx(np.sqrt(1000.0))
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


class TestGradCompressionMath:
    def test_roundtrip_error_small(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        rt = gc.simulate_roundtrip(g)
        rel = float(jnp.linalg.norm(g - rt) / jnp.linalg.norm(g))
        assert rel < 0.01, rel

    def test_error_feedback_telescopes(self):
        """Accumulated EF-compressed updates converge to the true sum."""
        key = jax.random.PRNGKey(1)
        true_sum = jnp.zeros((512,))
        applied = jnp.zeros((512,))
        err = jnp.zeros((512,))
        for i in range(50):
            key, sk = jax.random.split(key)
            g = jax.random.normal(sk, (512,)) * 0.1
            true_sum = true_sum + g
            target = g + err
            q = gc.simulate_roundtrip(target)
            err = target - q
            applied = applied + q
        # residual bounded by one-step quantization error, not accumulating
        resid = float(jnp.linalg.norm(true_sum - applied))
        one_step = float(jnp.linalg.norm(err))
        np.testing.assert_allclose(resid, one_step, rtol=1e-4)
        assert resid < 0.05 * float(jnp.linalg.norm(true_sum))
