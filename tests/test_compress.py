"""End-to-end compression quality: COALA vs baselines on a TRAINED model.

This is the paper's Table 2 story at smoke scale: train a small LM until it
clearly beats uniform CE, compress at a fixed ratio with each method, and
compare the CE degradation. COALA (context-aware) must beat plain SVD
(context-free), and regularized COALA_μ must not be worse than COALA_0 on
held-out batches.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CompressConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.core.calibrate import calibrate_model
from repro.core.compress import compress_model, compression_summary
from repro.data import DataConfig, TokenPipeline, calibration_stream
from repro.models import build_model
from repro.models.common import CPU_CTX
from repro.train.train_loop import make_train_state, make_train_step


@pytest.fixture(scope="module")
def trained_model():
    cfg = get_smoke_config("llama3_1b")
    model = build_model(cfg)
    # effective data vocab 64 (< model vocab): learnable within ~100 CPU steps
    dcfg = DataConfig(vocab_size=64, seq_len=64, global_batch=8, seed=11)
    pipe = TokenPipeline(dcfg, cfg)
    tcfg = TrainConfig(lr=5e-3, warmup_steps=5, total_steps=100,
                       schedule="cosine", compute_dtype="float32")
    state = make_train_state(model, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tcfg, CPU_CTX))
    for i in range(100):
        state, metrics = step(state, pipe.get_batch(i))
    params = state["params"]

    def eval_ce(p):
        ces = [float(model.loss(p, pipe.get_batch(1000 + i),
                                compute_dtype=jnp.float32)[0])
               for i in range(4)]
        return float(np.mean(ces))

    base_ce = eval_ce(params)
    # clearly learned: far below uniform-over-model-vocab (log 256 = 5.55)
    # and at/below uniform-over-the-restricted-support (log 64 = 4.16)
    assert base_ce < np.log(cfg.vocab_size) - 1.2, base_ce
    cal = calibrate_model(model, params,
                          [pipe.get_batch(2000 + i) for i in range(4)])
    return cfg, model, params, cal, eval_ce, base_ce


def _compress_ce(trained, method, ratio=0.55, **kw):
    cfg, model, params, cal, eval_ce, _ = trained
    ccfg = CompressConfig(method=method, ratio=ratio, **kw)
    cparams, reports = compress_model(model, params, cal, ccfg)
    return eval_ce(cparams), reports


def test_ratio_respected(trained_model):
    _, reports = _compress_ce(trained_model, "coala", ratio=0.5, mu=0.0)
    s = compression_summary(reports)
    assert 0.35 <= s["kept_ratio"] <= 0.55, s


def test_coala_beats_plain_svd(trained_model):
    ce_coala, _ = _compress_ce(trained_model, "coala", mu=0.0)
    ce_svd, _ = _compress_ce(trained_model, "svd")
    base = trained_model[5]
    assert ce_coala <= ce_svd + 1e-3, (ce_coala, ce_svd, base)


def test_regularization_not_worse(trained_model):
    ce_mu0, _ = _compress_ce(trained_model, "coala", mu=0.0)
    ce_mu, _ = _compress_ce(trained_model, "coala", mu=-1.0, lam=4.0)
    # λ-selected μ should be at least competitive on held-out data
    assert ce_mu <= ce_mu0 + 0.05, (ce_mu, ce_mu0)


def test_rsvd_close_to_exact(trained_model):
    ce_exact, _ = _compress_ce(trained_model, "coala", mu=0.0)
    ce_rsvd, _ = _compress_ce(trained_model, "coala", mu=0.0, use_rsvd=True,
                              rsvd_power_iters=3)
    assert abs(ce_rsvd - ce_exact) < 0.1, (ce_rsvd, ce_exact)


def test_factored_forward_equals_explicit_product(trained_model):
    cfg, model, params, cal, _, _ = trained_model
    cparams, _ = compress_model(model, params, cal,
                                CompressConfig(method="coala", ratio=0.5,
                                               mu=0.0))
    # pick one factored leaf and check (x@b_t)@a_t == x@(b_t@a_t)
    import jax.tree_util as jtu
    flat = jtu.tree_flatten_with_path(cparams)[0]
    bts = [(p, l) for p, l in flat if any(
        getattr(k, "key", "") == "b_t" for k in p)]
    assert bts, "no factored layers found"


def test_compressed_param_count_decreases(trained_model):
    cfg, model, params, cal, _, _ = trained_model
    cparams, reports = compress_model(model, params, cal,
                                      CompressConfig(method="coala",
                                                     ratio=0.5, mu=0.0))
    n0 = sum(x.size for x in jax.tree.leaves(params))
    n1 = sum(x.size for x in jax.tree.leaves(cparams))
    assert n1 < n0


def test_whisper_encdec_compression():
    """Enc-dec calibration: cross-attn K/V weights see encoder outputs as X."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("whisper_base")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=2), cfg)
    batches = [pipe.get_batch(i) for i in range(2)]
    cal = calibrate_model(model, params, batches)
    assert any(p.startswith("enc/") for p in cal.streams)
    assert any("/cross/" in p for p in cal.streams)
    cp, reports = compress_model(model, params, cal,
                                 CompressConfig(method="coala", ratio=0.6,
                                                lam=4.0))
    assert reports
    l1, _ = model.loss(cp, batches[0], compute_dtype=jnp.float32)
    assert np.isfinite(float(l1))


def test_per_expert_moe_compression():
    """Each routed expert compresses against its OWN routed-token activations
    (the paper's limited-data regime) and the factored experts execute."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("deepseek_moe_16b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=4), cfg)
    batches = [pipe.get_batch(i) for i in range(2)]
    cal = calibrate_model(model, params, batches)
    assert any("/expert" in p for p in cal.streams), "no per-expert capture"
    cp, reports = compress_model(model, params, cal,
                                 CompressConfig(method="coala", ratio=0.6,
                                                lam=4.0))
    # factored expert banks are (b_t, a_t) tuples
    blk = jax.tree.map(lambda a: a[0], cp["blocks"])
    assert isinstance(blk["sub0"]["ffn"]["w_gate"], tuple)
    l1, _ = model.loss(cp, batches[0], compute_dtype=jnp.float32)
    assert np.isfinite(float(l1))


def test_adaptive_rank_beats_uniform(trained_model):
    """Water-filling rank allocation (beyond-paper) must achieve lower total
    weighted error than the uniform ratio at the SAME parameter budget."""
    cfg, model, params, cal, eval_ce, _ = trained_model
    ce_uniform, rep_u = _compress_ce(trained_model, "coala", ratio=0.5, mu=0.0)
    ce_adaptive, rep_a = _compress_ce(trained_model, "coala", ratio=0.5,
                                      mu=0.0, adaptive_rank=True)
    s_u = compression_summary(rep_u)
    s_a = compression_summary(rep_a)
    # same budget (within one rank-granularity step per layer)
    assert abs(s_a["params_after"] - s_u["params_after"]) \
        <= 0.1 * s_u["params_after"], (s_a, s_u)
    # adaptive allocation gives varied ranks
    ranks = {r.rank for r in rep_a}
    assert len(ranks) > 1, "adaptive allocation degenerated to uniform"
    # and should not hurt quality at the same budget
    assert ce_adaptive <= ce_uniform + 0.05, (ce_adaptive, ce_uniform)


# --------------------------------------------------------------------------
# Calibrator streaming invariances: the R factor a layer ends up with must
# depend only on WHAT activations streamed in, never on how the stream was
# chunked, batched, or ordered. Live-traffic recalibration
# (serve/recalibrate.py) leans on exactly this: requests arrive in arbitrary
# order and are captured incrementally, yet the traffic R must match an
# offline calibration over the same rows. R itself is only unique up to row
# signs/orthogonal factors, so equality is asserted on RᵀR (= XᵀX).


def _gram_rel_err(r1, r2):
    g1, g2 = r1.T @ r1, r2.T @ r2
    return float(jnp.linalg.norm(g1 - g2) / jnp.maximum(
        jnp.linalg.norm(g2), 1e-12))


def _stream_rows(rows, *, chunks, max_tokens=8192, order=None):
    from repro.core.calibrate import Calibrator
    cal = Calibrator(max_tokens_per_record=max_tokens)
    parts = np.array_split(rows, chunks)
    if order is not None:
        parts = [parts[i] for i in order]
    for part in parts:
        if len(part):
            cal.record("layer", jnp.asarray(part))
    return cal.r_factors()["layer"]


def test_calibrator_chunk_size_invariance():
    """RᵀR is invariant to max_tokens_per_record (TSQR fold granularity)."""
    rows = np.random.RandomState(0).randn(300, 24).astype(np.float32)
    ref = _stream_rows(rows, chunks=1)
    for max_tokens in (7, 64, 301):
        r = _stream_rows(rows, chunks=1, max_tokens=max_tokens)
        assert _gram_rel_err(r, ref) < 1e-5, max_tokens


def test_calibrator_record_batching_invariance():
    """One big record() call == many small ones over the same rows."""
    rows = np.random.RandomState(1).randn(256, 16).astype(np.float32)
    ref = _stream_rows(rows, chunks=1)
    for chunks in (2, 5, 17):
        r = _stream_rows(rows, chunks=chunks)
        assert _gram_rel_err(r, ref) < 1e-5, chunks


def test_calibrator_order_invariance():
    """Permuting the record-call order leaves RᵀR unchanged: TSQR folds
    commute on the Gram level (each fold is an orthogonal reduction)."""
    rows = np.random.RandomState(2).randn(240, 16).astype(np.float32)
    ref = _stream_rows(rows, chunks=6)
    for seed in (3, 4):
        order = np.random.RandomState(seed).permutation(6)
        r = _stream_rows(rows, chunks=6, order=list(order))
        assert _gram_rel_err(r, ref) < 1e-5, seed


def test_calibrator_invariance_ill_conditioned():
    """Pinned hard case: column scales spanning 6 decades (cond(X) ~ 1e6,
    the paper's Fig. 1 regime). The QR-based stream must still be
    chunking/order-invariant — the Gram-free path exists precisely so this
    case doesn't lose the small directions to cancellation. The tolerance
    is looser than the well-conditioned cases' (RᵀR itself squares the
    conditioning) but pinned, so a silent regression to Gram-style
    accumulation fails loudly."""
    rng = np.random.RandomState(5)
    rows = rng.randn(200, 12).astype(np.float32)
    rows *= np.logspace(0, -6, 12, dtype=np.float32)[None, :]
    ref = _stream_rows(rows, chunks=1)
    for chunks, max_tokens, seed in ((4, 8192, None), (1, 13, None),
                                     (8, 8192, 6)):
        order = (None if seed is None
                 else list(np.random.RandomState(seed).permutation(chunks)))
        r = _stream_rows(rows, chunks=chunks, max_tokens=max_tokens,
                         order=order)
        assert _gram_rel_err(r, ref) < 1e-3, (chunks, max_tokens, seed)


def test_calibrator_reset():
    """reset() drops every accumulated stream/Gram but keeps the instance
    usable — a fresh window must equal a fresh Calibrator exactly."""
    from repro.core.calibrate import Calibrator
    rng = np.random.RandomState(7)
    a = rng.randn(40, 8).astype(np.float32)
    b = rng.randn(56, 8).astype(np.float32)
    cal = Calibrator(collect_gram=True)
    cal.record("layer", jnp.asarray(a))
    assert cal.tokens_seen() == {"layer": 40} and cal.grams
    cal.reset()
    assert cal.streams == {} and cal.grams == {}
    assert cal.tokens_seen() == {} and cal.r_factors() == {}
    cal.record("layer", jnp.asarray(b))
    fresh = Calibrator()
    fresh.record("layer", jnp.asarray(b))
    assert cal.tokens_seen() == {"layer": 56}
    assert _gram_rel_err(cal.r_factors()["layer"],
                         fresh.r_factors()["layer"]) < 1e-6


def test_calibrator_record_has_no_lazy_imports():
    """record() runs per captured activation on the serving path; the old
    per-call ``from repro.kernels import ops`` re-entered the import lock
    every record. The import must stay hoisted to module scope."""
    import inspect
    from repro.core.calibrate import Calibrator
    src = inspect.getsource(Calibrator.record)
    assert "import" not in src, src
