"""Docs checker: dead intra-repo links/anchors + serving-flag coverage.

Run from anywhere (resolves paths relative to the repo root); exits nonzero
with one line per problem. CI runs this as the ``docs`` job; it is also
wrapped by ``tests/test_docs.py`` so a local tier-1 run catches the same
breakage. Pure stdlib — no jax, no pip installs.

Checks:
  1. Every markdown link in README.md and docs/*.md that points inside the
     repo resolves to an existing file (http(s)/mailto links are skipped).
  2. Every ``#anchor`` fragment on an intra-repo markdown link matches a
     heading in the target file (GitHub-style slugs, duplicate-aware).
  3. Every argparse flag registered in src/repro/launch/serve.py appears
     literally (e.g. ``--block-size``) in docs/serving.md.
  4. Every mesh-related argparse flag in src/repro/launch/train.py and
     src/repro/launch/compress.py (--mesh, --coordinator, --process-id,
     --num-processes, --grad-compress, ...) appears literally in
     docs/distributed.md.
  5. Every observability flag in src/repro/launch/serve.py and
     src/repro/launch/compress.py (--trace-out, --metrics-out,
     --numerics-report) appears literally in docs/observability.md.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

# inline links, with or without a title: [x](target) / [x](target "title")
LINK_RE = re.compile(r"\[[^\]]*\]\(\s*<?([^)\s>]+)>?[^)]*\)")
# reference-style definitions: [id]: target
DEF_RE = re.compile(r"^\s*\[[^\]]+\]:\s*(\S+)", re.MULTILINE)
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FLAG_RE = re.compile(r"add_argument\(\s*\"(--[a-z][a-z0-9-]*)\"")


def github_slug(heading: str, seen: dict) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->hyphens,
    ``-N`` suffixes for duplicates."""
    s = re.sub(r"[`*_]", "", heading.strip()).lower()
    s = re.sub(r"[^\w\- ]", "", s)
    s = s.replace(" ", "-")
    n = seen.get(s, 0)
    seen[s] = n + 1
    return s if n == 0 else f"{s}-{n}"


def anchors_of(md_path: pathlib.Path) -> set:
    seen: dict = {}
    return {github_slug(h, seen)
            for h in HEADING_RE.findall(md_path.read_text())}


def check_links(md_files) -> list:
    errors = []
    for md in md_files:
        text = md.read_text()
        for target in LINK_RE.findall(text) + DEF_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, frag = target.partition("#")
            dest = (md.parent / path_part).resolve() if path_part else md
            if not dest.exists():
                errors.append(f"{md.relative_to(ROOT)}: dead link -> {target}")
                continue
            if frag:
                if dest.suffix != ".md":
                    errors.append(f"{md.relative_to(ROOT)}: anchor on "
                                  f"non-markdown target -> {target}")
                elif frag not in anchors_of(dest):
                    errors.append(f"{md.relative_to(ROOT)}: dead anchor "
                                  f"-> {target}")
    return errors


def check_serve_flags() -> list:
    serve_py = ROOT / "src" / "repro" / "launch" / "serve.py"
    serving_md = ROOT / "docs" / "serving.md"
    if not serving_md.exists():
        return ["docs/serving.md is missing"]
    doc = serving_md.read_text()
    flags = FLAG_RE.findall(serve_py.read_text())
    if not flags:
        return ["no argparse flags found in launch/serve.py (regex drift?)"]
    return [f"docs/serving.md: undocumented launch/serve.py flag {f}"
            for f in flags if f not in doc]


# a launcher flag is "mesh-related" (and must be documented in
# docs/distributed.md) if it matches this — keep in sync with the
# distributed-subsystem flag vocabulary
MESH_FLAG_RE = re.compile(
    r"mesh|coordinator|process|shard|grad-compress|zero")


def check_dist_flags() -> list:
    dist_md = ROOT / "docs" / "distributed.md"
    if not dist_md.exists():
        return ["docs/distributed.md is missing"]
    doc = dist_md.read_text()
    errors = []
    found_any = False
    for launcher in ("train.py", "compress.py"):
        src = ROOT / "src" / "repro" / "launch" / launcher
        flags = [f for f in FLAG_RE.findall(src.read_text())
                 if MESH_FLAG_RE.search(f)]
        found_any = found_any or bool(flags)
        errors += [f"docs/distributed.md: undocumented launch/{launcher} "
                   f"mesh flag {f}" for f in flags if f not in doc]
    if not found_any:
        errors.append("no mesh-related argparse flags found in "
                      "launch/train.py or launch/compress.py (regex drift?)")
    return errors


# every observability flag a launcher grows must be documented in
# docs/observability.md — keep in sync with the obs-subsystem flag
# vocabulary (tracing, metrics export, numerics reports, the live
# telemetry plane: HTTP endpoints, flight recorder, SLO targets)
OBS_FLAG_RE = re.compile(
    r"trace-out|metrics-out|numerics|telemetry|flight-recorder|slo-|"
    r"trace-max-events")


def check_obs_flags() -> list:
    obs_md = ROOT / "docs" / "observability.md"
    if not obs_md.exists():
        return ["docs/observability.md is missing"]
    doc = obs_md.read_text()
    errors = []
    found_any = False
    for launcher in ("serve.py", "compress.py"):
        src = ROOT / "src" / "repro" / "launch" / launcher
        flags = [f for f in FLAG_RE.findall(src.read_text())
                 if OBS_FLAG_RE.search(f)]
        found_any = found_any or bool(flags)
        errors += [f"docs/observability.md: undocumented launch/{launcher} "
                   f"obs flag {f}" for f in flags if f not in doc]
    if not found_any:
        errors.append("no observability argparse flags found in "
                      "launch/serve.py or launch/compress.py (regex drift?)")
    return errors


def main() -> int:
    md_files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    missing = [m for m in md_files if not m.exists()]
    errors = [f"missing doc file: {m.relative_to(ROOT)}" for m in missing]
    errors += check_links([m for m in md_files if m.exists()])
    errors += check_serve_flags()
    errors += check_dist_flags()
    errors += check_obs_flags()
    for e in errors:
        print(f"ERROR: {e}")
    if not errors:
        print(f"docs OK: {len(md_files)} files, all links/anchors resolve, "
              "all serving + mesh + observability flags documented")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
