"""Render pytest junit XML into a GitHub Actions step summary.

CI runs this ``if: always()`` right after each pytest step, so a red run
shows its failures (with messages) and its slowest tests on the run's
summary page instead of burying them in a 10k-line log::

    python tools/junit_summary.py pytest-junit*.xml

Writes GitHub-flavored markdown to ``$GITHUB_STEP_SUMMARY`` when set (the
Actions contract: appending to that file renders on the run page) and
always mirrors it to stdout, so the tool is greppable locally too. Per
junit file: the pass/fail/error/skip tally and total wall time, every
failure or error with its condensed message, and the top-10 slowest tests.
Missing artifacts are reported but do not fail the tool — it must never
mask the pytest step's own exit code (the summary of a crashed run is
"file missing", not a second failure). Pure stdlib.
"""
from __future__ import annotations

import os
import pathlib
import sys
import xml.etree.ElementTree as ET

SLOWEST = 10


def _case_id(case: ET.Element) -> str:
    cls = case.get("classname") or ""
    name = case.get("name") or "?"
    return f"{cls}::{name}" if cls else name


def _message(node: ET.Element) -> str:
    msg = (node.get("message") or (node.text or "").strip()
           or node.tag).splitlines()
    first = next((ln.strip() for ln in msg if ln.strip()), node.tag)
    return first[:300]


def summarize(path: pathlib.Path) -> str:
    try:
        root = ET.parse(path).getroot()
    except (OSError, ET.ParseError) as e:
        return f"### `{path.name}`\n\n_unreadable junit file: {e}_\n"
    suites = root.iter("testsuite") if root.tag == "testsuites" else [root]
    cases, tally = [], {"tests": 0, "failures": 0, "errors": 0, "skipped": 0}
    wall = 0.0
    for suite in suites:
        for k in tally:
            tally[k] += int(suite.get(k) or 0)
        wall += float(suite.get("time") or 0.0)
        cases.extend(suite.iter("testcase"))
    passed = (tally["tests"] - tally["failures"] - tally["errors"]
              - tally["skipped"])
    status = "✅" if tally["failures"] + tally["errors"] == 0 else "❌"
    lines = [f"### {status} `{path.name}` — {passed} passed, "
             f"{tally['failures']} failed, {tally['errors']} errors, "
             f"{tally['skipped']} skipped in {wall:.1f}s", ""]
    bad = [(c, n) for c in cases
           for n in c if n.tag in ("failure", "error")]
    if bad:
        lines += ["| failed test | message |", "|---|---|"]
        lines += [f"| `{_case_id(c)}` | {_message(n)} |" for c, n in bad]
        lines.append("")
    timed = sorted(cases, key=lambda c: float(c.get("time") or 0.0),
                   reverse=True)[:SLOWEST]
    if timed:
        lines += [f"<details><summary>top {len(timed)} slowest</summary>", "",
                  "| test | seconds |", "|---|---|"]
        lines += [f"| `{_case_id(c)}` | {float(c.get('time') or 0.0):.2f} |"
                  for c in timed]
        lines += ["", "</details>", ""]
    return "\n".join(lines)


def main(argv) -> int:
    if not argv:
        print("usage: junit_summary.py JUNIT_XML [...]", file=sys.stderr)
        return 2
    chunks = []
    for a in argv:
        path = pathlib.Path(a)
        if not path.exists():
            chunks.append(f"### `{path.name}`\n\n_file missing (step "
                          "crashed before writing junit output?)_\n")
        else:
            chunks.append(summarize(path))
    doc = "\n".join(chunks)
    print(doc)
    out = os.environ.get("GITHUB_STEP_SUMMARY")
    if out:
        with open(out, "a") as f:
            f.write(doc + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
