#!/usr/bin/env python3
"""Lint a Prometheus text-exposition file (format 0.0.4).

CI runs this over the ``--metrics-out`` file the serve smoke writes, so a
malformed exposition fails the fast tier instead of silently producing an
unscrapeable artifact. Importable: ``lint(text)`` returns a list of error
strings (empty = clean); the CLI exits non-zero and prints them.

Checks:
  * every non-comment line is ``name[{labels}] value`` with a legal metric
    name and a parseable float value;
  * ``# TYPE`` lines name a known type and precede their metric's samples;
  * no metric is TYPE-declared twice;
  * every metric family has a ``# HELP`` line with a non-empty help string
    (and no family is HELP-declared twice) — an undocumented metric is a
    lint error, not a style choice;
  * counters end in ``_total``;
  * histograms expose ``_bucket`` samples with non-decreasing cumulative
    counts, a ``+Inf`` bucket, and ``_sum``/``_count`` samples where
    ``_count`` equals the ``+Inf`` bucket.

Usage: python tools/check_prom.py METRICS_serve.prom
"""
from __future__ import annotations

import math
import re
import sys
from typing import Dict, List

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>\S+)(?:\s+\d+)?$")
LABEL_RE = re.compile(r'^[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"$')
TYPES = {"counter", "gauge", "histogram", "summary", "untyped"}


def _parse_value(raw: str) -> float:
    if raw == "+Inf":
        return math.inf
    if raw == "-Inf":
        return -math.inf
    return float(raw)


def lint(text: str) -> List[str]:
    errors: List[str] = []
    types: Dict[str, str] = {}
    helps: Dict[str, str] = {}
    seen_samples: set = set()
    # histogram bookkeeping: name -> {"buckets": [(le, cum)], "sum": bool,
    #                                 "count": value}
    hists: Dict[str, dict] = {}

    def base_of(sample: str) -> str:
        for suf in ("_bucket", "_sum", "_count"):
            if sample.endswith(suf) and sample[: -len(suf)] in types:
                return sample[: -len(suf)]
        return sample

    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line.split(" ", 3)          # "#", "HELP", name, text
            if len(parts) < 3 or not NAME_RE.match(parts[2]):
                errors.append(f"line {ln}: malformed HELP line: {line!r}")
                continue
            name = parts[2]
            help_text = parts[3].strip() if len(parts) == 4 else ""
            if not help_text:
                errors.append(f"line {ln}: empty HELP text for {name!r}")
            if name in helps:
                errors.append(f"line {ln}: duplicate HELP for {name!r}")
            helps[name] = help_text
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4:
                errors.append(f"line {ln}: malformed TYPE line: {line!r}")
                continue
            _, _, name, mtype = parts
            if not NAME_RE.match(name):
                errors.append(f"line {ln}: bad metric name {name!r}")
            if mtype not in TYPES:
                errors.append(f"line {ln}: unknown type {mtype!r}")
            if name in types:
                errors.append(f"line {ln}: duplicate TYPE for {name!r}")
            types[name] = mtype
            if mtype == "counter" and not name.endswith("_total"):
                errors.append(
                    f"line {ln}: counter {name!r} should end in _total")
            if mtype == "histogram":
                hists[name] = {"buckets": [], "sum": False, "count": None}
            continue
        if line.startswith("#"):
            continue
        m = SAMPLE_RE.match(line)
        if not m:
            errors.append(f"line {ln}: unparseable sample: {line!r}")
            continue
        name, labels, raw = m["name"], m["labels"], m["value"]
        try:
            value = _parse_value(raw)
        except ValueError:
            errors.append(f"line {ln}: bad value {raw!r} for {name!r}")
            continue
        if labels:
            for lab in labels.split(","):
                if not LABEL_RE.match(lab.strip()):
                    errors.append(f"line {ln}: bad label {lab.strip()!r}")
        base = base_of(name)
        if base not in types:
            errors.append(f"line {ln}: sample {name!r} has no TYPE line")
        key = (name, labels or "")
        if key in seen_samples:
            errors.append(f"line {ln}: duplicate sample {name!r}"
                          f"{{{labels or ''}}}")
        seen_samples.add(key)
        if base in hists:
            h = hists[base]
            if name == f"{base}_bucket":
                le = dict(
                    lab.strip().split("=", 1)
                    for lab in (labels or "").split(",") if "=" in lab
                ).get("le", "").strip('"')
                try:
                    h["buckets"].append((_parse_value(le), value))
                except ValueError:
                    errors.append(f"line {ln}: bucket of {base!r} has bad "
                                  f"le={le!r}")
            elif name == f"{base}_sum":
                h["sum"] = True
            elif name == f"{base}_count":
                h["count"] = value
            elif name == base:
                errors.append(f"line {ln}: histogram {base!r} has a bare "
                              f"sample (expected _bucket/_sum/_count)")

    for name, h in hists.items():
        if not h["buckets"]:
            errors.append(f"histogram {name!r}: no _bucket samples")
            continue
        les = [le for le, _ in h["buckets"]]
        cums = [c for _, c in h["buckets"]]
        if les != sorted(les):
            errors.append(f"histogram {name!r}: le bounds not increasing")
        if any(b < a for a, b in zip(cums, cums[1:])):
            errors.append(
                f"histogram {name!r}: cumulative bucket counts decrease")
        if not les or les[-1] != math.inf:
            errors.append(f"histogram {name!r}: missing +Inf bucket")
        if not h["sum"]:
            errors.append(f"histogram {name!r}: missing _sum")
        if h["count"] is None:
            errors.append(f"histogram {name!r}: missing _count")
        elif les and les[-1] == math.inf and h["count"] != cums[-1]:
            errors.append(f"histogram {name!r}: _count {h['count']} != "
                          f"+Inf bucket {cums[-1]}")

    # every family must carry documentation: a TYPE-declared metric with no
    # HELP line is as unscrapeable-in-practice as a malformed sample
    for name in types:
        if name not in helps:
            errors.append(f"metric {name!r}: missing HELP line")
    return errors


def main(argv: List[str]) -> int:
    if len(argv) != 2:
        print(__doc__)
        return 2
    with open(argv[1]) as f:
        text = f.read()
    errors = lint(text)
    for e in errors:
        print(f"check_prom: {argv[1]}: {e}", file=sys.stderr)
    if not errors:
        n = len([l for l in text.splitlines()
                 if l.strip() and not l.startswith("#")])
        print(f"check_prom: {argv[1]}: OK ({n} samples)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
