"""CI perf-regression gate over BENCH_*.json artifacts.

Validates the JSON artifacts ``benchmarks.run --json`` writes against
committed baselines in ``benchmarks/baselines/`` and exits nonzero with one
line per problem. Pure stdlib — no jax, no pip installs; CI runs it right
after the bench smoke steps, so a regression fails the PR instead of
landing as a quietly worse artifact.

Checks, per artifact:

  1. **Integrity** — the file parses, its ``errors`` map (written by
     ``benchmarks.run`` when a suite raises or emits no rows) is empty, row
     names are unique, and no row value is null/empty/NaN/inf.
  2. **Schema completeness, both ways** — every baseline row is present in
     the artifact (a silently dropped metric is a regression) and every
     artifact row is present in the baseline (a new metric must be
     baselined, not invisible to the gate).
  3. **Hard invariants** — non-negotiable acceptance rows enforced from
     this file, not the baseline, so editing a baseline can never relax
     them: ``serve/post_warmup_compiles == 0``, ``serve/obs_overhead_pct <
     5`` (measured with the full telemetry plane on: server + flight
     recorder + SLO accounting), ``serve/slo_goodput == 1`` (uncontended
     smoke traffic must meet its generous SLOs — a goodput dip on an idle
     box is an accounting bug, not load),
     ``serve/paged_vs_gather_decode_speedup >= 1``, the speculative
     rows (``serve/spec_greedy_parity == 1``, ``serve/spec_accept_rate >
     0``, ``serve/spec_decode_speedup >= 1``,
     ``serve/spec_post_warmup_compiles == 0``), the live-recalibration
     rows (``serve/recalib_swaps >= 1`` — at least one bound-cleared
     hot-swap, ``serve/recalib_post_warmup_compiles == 0`` — swaps never
     retrace, ``serve/recalib_greedy_parity == 1`` — identity swaps are
     token-exact, ``serve/recalib_r_gram_rel_err < 1e-3`` — traffic
     calibration matches offline replay) and ``dist/r_gram_rel_err <
     1e-3`` (each required whenever the artifact ran that suite).
  4. **Baseline comparisons** — each baseline row carries a ``kind``:
       * ``band``: value within ±``band_pct``% of the baseline value
         (default 40 — CPU CI wall times are noisy; per-row ``band_pct``
         overrides tighten or loosen it).
       * ``min`` / ``max``: one-sided floor/ceiling.
       * ``present``: the row must exist with a sane value, nothing more
         (latency rows on shared CI hardware live here).

Refreshing a baseline after an intentional perf change::

    PYTHONPATH=src python -m benchmarks.run serve --smoke --json BENCH_serve.json
    python tools/check_bench.py --update BENCH_serve.json

``--update`` rewrites the committed baseline from the artifact: existing
rows keep their kind and overrides (only the reference value moves), new
rows default to ``band`` for throughput (``*tok_per_s`` / ``*req_per_s``)
and ``present`` otherwise, and rows the artifact no longer emits are
dropped. Commit the diff with the PR that changed the numbers; the
workflow is documented in docs/benchmarks.md.
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
BASELINE_DIR = ROOT / "benchmarks" / "baselines"
DEFAULT_BAND_PCT = 40.0

# suite name -> rows the gate enforces whenever that suite ran, regardless
# of what any baseline says (op, threshold)
HARD_INVARIANTS = {
    "serve": [
        ("serve/post_warmup_compiles", "==", 0.0),
        ("serve/obs_overhead_pct", "<", 5.0),
        ("serve/slo_goodput", "==", 1.0),
        ("serve/paged_vs_gather_decode_speedup", ">=", 1.0),
        ("serve/spec_greedy_parity", "==", 1.0),
        ("serve/spec_accept_rate", ">", 0.0),
        ("serve/spec_decode_speedup", ">=", 1.0),
        ("serve/spec_post_warmup_compiles", "==", 0.0),
        ("serve/recalib_swaps", ">=", 1.0),
        ("serve/recalib_post_warmup_compiles", "==", 0.0),
        ("serve/recalib_greedy_parity", "==", 1.0),
        ("serve/recalib_r_gram_rel_err", "<", 1e-3),
    ],
    "dist": [
        ("dist/r_gram_rel_err", "<", 1e-3),
    ],
}

def _rel(path: pathlib.Path) -> str:
    try:
        return str(path.relative_to(ROOT))
    except ValueError:
        return str(path)


_OPS = {
    "==": lambda v, t: v == t,
    "<": lambda v, t: v < t,
    "<=": lambda v, t: v <= t,
    ">=": lambda v, t: v >= t,
    ">": lambda v, t: v > t,
}


def _num(value):
    """Parse a row value; returns (float, None) or (None, reason)."""
    if value is None:
        return None, "null value"
    if isinstance(value, bool):
        return float(value), None
    if isinstance(value, (int, float)):
        v = float(value)
    else:
        s = str(value).strip()
        if not s:
            return None, "empty value"
        try:
            v = float(s)
        except ValueError:
            return None, "non-numeric"
    if not math.isfinite(v):
        return None, f"non-finite value {value!r}"
    return v, None


def _rows_by_name(artifact: dict, errors: list, label: str) -> dict:
    rows = {}
    for row in artifact.get("rows", []):
        name = row.get("name")
        if not name:
            errors.append(f"{label}: row without a name: {row!r}")
            continue
        if name in rows:
            errors.append(f"{label}: duplicate row {name}")
        rows[name] = row.get("value")
    return rows


def default_kind(name: str) -> str:
    return ("band" if name.endswith(("tok_per_s", "req_per_s"))
            else "present")


def check_artifact(path: pathlib.Path, baseline_path: pathlib.Path) -> list:
    label = path.name
    try:
        artifact = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        return [f"{label}: unreadable artifact: {e}"]
    errors: list = []
    for suite, msg in (artifact.get("errors") or {}).items():
        errors.append(f"{label}: suite {suite} failed in benchmarks.run: "
                      f"{msg}")
    rows = _rows_by_name(artifact, errors, label)
    if not rows:
        errors.append(f"{label}: artifact has no rows")
        return errors

    # integrity: every value must be sane (finite if it parses at all)
    numeric: dict = {}
    for name, value in rows.items():
        v, why = _num(value)
        if v is None and why != "non-numeric":
            errors.append(f"{label}: row {name}: {why}")
        elif v is not None:
            numeric[name] = v

    # hard invariants: enforced from this file whenever the suite ran
    for suite in artifact.get("benchmarks", []):
        for name, op, thresh in HARD_INVARIANTS.get(suite, []):
            if name not in rows:
                errors.append(f"{label}: hard-invariant row {name} missing "
                              f"(suite {suite} ran)")
            elif name not in numeric:
                errors.append(f"{label}: hard-invariant row {name} is not "
                              f"numeric: {rows[name]!r}")
            elif not _OPS[op](numeric[name], thresh):
                errors.append(f"{label}: hard invariant violated: {name} = "
                              f"{numeric[name]:g}, required {op} {thresh:g}")

    if not baseline_path.exists():
        errors.append(
            f"{label}: no committed baseline at {_rel(baseline_path)} — "
            f"generate the artifact and run tools/check_bench.py "
            f"--update {path}")
        return errors
    baseline = json.loads(baseline_path.read_text())
    base_rows = baseline.get("rows", {})
    band_default = float(baseline.get("default_band_pct", DEFAULT_BAND_PCT))

    # schema completeness, both directions
    for name in sorted(set(base_rows) - set(rows)):
        errors.append(f"{label}: baseline row {name} missing from artifact")
    for name in sorted(set(rows) - set(base_rows)):
        errors.append(f"{label}: row {name} not in baseline — rerun "
                      f"tools/check_bench.py --update after reviewing it")

    for name, spec in sorted(base_rows.items()):
        if name not in rows:
            continue
        kind = spec.get("kind", "present")
        if kind == "present":
            continue
        if name not in numeric:
            errors.append(f"{label}: row {name} must be numeric for "
                          f"kind={kind}, got {rows[name]!r}")
            continue
        v = numeric[name]
        ref = float(spec.get("value", 0.0))
        if kind == "band":
            pct = float(spec.get("band_pct", band_default))
            lo, hi = ref * (1 - pct / 100), ref * (1 + pct / 100)
            if ref < 0:
                lo, hi = hi, lo
            if not lo <= v <= hi:
                errors.append(
                    f"{label}: {name} = {v:g} outside ±{pct:g}% of "
                    f"baseline {ref:g} [{lo:g}, {hi:g}]")
        elif kind == "min":
            if v < ref:
                errors.append(f"{label}: {name} = {v:g} below baseline "
                              f"floor {ref:g}")
        elif kind == "max":
            if v > ref:
                errors.append(f"{label}: {name} = {v:g} above baseline "
                              f"ceiling {ref:g}")
        else:
            errors.append(f"{label}: baseline row {name} has unknown "
                          f"kind {kind!r}")
    return errors


def update_baseline(path: pathlib.Path, baseline_path: pathlib.Path) -> list:
    try:
        artifact = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        return [f"{path.name}: unreadable artifact: {e}"]
    errors: list = []
    for suite, msg in (artifact.get("errors") or {}).items():
        errors.append(f"{path.name}: refusing to baseline a failed run "
                      f"(suite {suite}: {msg})")
    rows = _rows_by_name(artifact, errors, path.name)
    if errors:
        return errors
    old = {}
    if baseline_path.exists():
        old = json.loads(baseline_path.read_text()).get("rows", {})
    out = {}
    for name in sorted(rows):
        v, _ = _num(rows[name])
        spec = dict(old.get(name, {"kind": default_kind(name)}))
        if spec.get("kind") in ("band", "min", "max"):
            if v is None:
                errors.append(f"{path.name}: row {name} is kind="
                              f"{spec['kind']} but not numeric: "
                              f"{rows[name]!r}")
                continue
            spec["value"] = v
        else:
            spec.pop("value", None)
        out[name] = spec
    if errors:
        return errors
    baseline_path.parent.mkdir(parents=True, exist_ok=True)
    doc = {"source": path.name,
           "benchmarks": artifact.get("benchmarks", []),
           "smoke": artifact.get("smoke", False),
           "default_band_pct": DEFAULT_BAND_PCT,
           "rows": out}
    baseline_path.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {_rel(baseline_path)} ({len(out)} rows)")
    return []


def main() -> int:
    ap = argparse.ArgumentParser(
        description="validate BENCH_*.json against committed baselines")
    ap.add_argument("artifacts", nargs="+",
                    help="BENCH_*.json files written by benchmarks.run")
    ap.add_argument("--baseline-dir", default=str(BASELINE_DIR),
                    help="directory of committed baselines (default: "
                         "benchmarks/baselines/)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baselines from the artifacts instead "
                         "of validating (intentional perf changes)")
    args = ap.parse_args()
    errors = []
    for a in args.artifacts:
        path = pathlib.Path(a)
        baseline_path = pathlib.Path(args.baseline_dir) / path.name
        if args.update:
            errors += update_baseline(path, baseline_path)
        else:
            errors += check_artifact(path, baseline_path)
    for e in errors:
        print(f"ERROR: {e}")
    if not errors and not args.update:
        print(f"bench OK: {len(args.artifacts)} artifact(s) within baseline "
              "bands, hard invariants hold")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
