"""PEFT adapter-initialization comparison (paper §6.2, Table 4).

Initializes LoRA-style adapters with each method (LoRA / PiSSA / CorDA /
COALA α=1 / COALA α=2), fine-tunes the adapters only, and reports CE.

  PYTHONPATH=src python examples/finetune_adapters.py [--rank 8] [--steps 30]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig
from repro.configs import get_smoke_config
from repro.core.adapters import init_adapters, mask_grads, merge_adapters
from repro.core.calibrate import calibrate_model
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.models.common import CPU_CTX
from repro.train.optimizer import adamw_init, adamw_update
from repro.train.train_loop import make_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_1b")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    # pre-train on distribution A, fine-tune on distribution B
    pipe_a = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                      global_batch=8, seed=11), cfg)
    pipe_b = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                      global_batch=8, seed=99, noise=0.05), cfg)

    tcfg = TrainConfig(lr=3e-3, warmup_steps=5, total_steps=100,
                       schedule="cosine", compute_dtype="float32")
    state = make_train_state(model, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tcfg, CPU_CTX))
    for i in range(100):
        state, _ = step(state, pipe_a.get_batch(i))
    params = state["params"]

    cal = calibrate_model(model, params,
                          [pipe_b.get_batch(2000 + i) for i in range(3)])

    def eval_b(p):
        return float(np.mean([float(model.loss(p, pipe_b.get_batch(1000 + i),
                                               compute_dtype=jnp.float32)[0])
                              for i in range(3)]))

    print(f"pre-trained model on task B: CE={eval_b(params):.4f}\n")
    ft_cfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=args.steps,
                         schedule="const", weight_decay=0.0)
    for method in ("lora", "pissa", "corda", "coala_a1", "coala_a2"):
        ap_, mask = init_adapters(params, cal.r_factors(), method=method,
                                  rank=args.rank)
        opt = adamw_init(ap_)

        @jax.jit
        def ft_step(p, o, batch):
            def lf(p):
                return model.loss(p, batch, compute_dtype=jnp.float32)[0]
            loss, g = jax.value_and_grad(lf)(p)
            g = mask_grads(g, mask)
            p, o, _ = adamw_update(ft_cfg, p, g, o)
            return p, o, loss

        for i in range(args.steps):
            ap_, opt, _ = ft_step(ap_, opt, pipe_b.get_batch(i))
        merged = merge_adapters(ap_)
        print(f"{method:10s}: CE on task B after {args.steps} adapter steps "
              f"= {eval_b(merged):.4f}")


if __name__ == "__main__":
    main()
