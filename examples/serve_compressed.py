"""Batched serving of a COALA-compressed model: prefill + decode loop,
dense-vs-compressed parameter counts, KV-cache reuse.

  PYTHONPATH=src python examples/serve_compressed.py [--ratio 0.6]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import CompressConfig
from repro.configs import get_smoke_config
from repro.core.calibrate import calibrate_model
from repro.core.compress import compress_model, compression_summary
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--ratio", type=float, default=0.6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=args.batch), cfg)

    cal = calibrate_model(model, params, [pipe.get_batch(i) for i in range(2)])
    cparams, reports = compress_model(
        model, params, cal, CompressConfig(method="coala", ratio=args.ratio,
                                           lam=4.0, mu=-1.0))
    s = compression_summary(reports)
    n0 = sum(x.size for x in jax.tree.leaves(params))
    n1 = sum(x.size for x in jax.tree.leaves(cparams))
    print(f"params: {n0/1e6:.2f}M -> {n1/1e6:.2f}M "
          f"(compressed layers kept {s['kept_ratio']:.0%})")

    prompt = pipe.get_batch(100)["tokens"][:, :8]
    for name, p in (("dense", params), ("coala", cparams)):
        eng = ServeEngine(model, p, compute_dtype=jnp.float32,
                          cache_dtype=jnp.float32)
        t0 = time.perf_counter()
        out = eng.generate(prompt, max_new_tokens=args.new_tokens)
        dt = time.perf_counter() - t0
        print(f"{name:6s}: generated {out.shape[0]}x{args.new_tokens} tokens "
              f"in {dt:.2f}s (incl. compile)")
    print("done ✓")


if __name__ == "__main__":
    main()
