"""Serving a COALA-compressed model: continuous batching over the paged KV
cache (mixed-length requests, staggered arrivals), dense vs compressed, with
the legacy fixed-batch loop as a cross-check.

  PYTHONPATH=src python examples/serve_compressed.py [--ratio 0.6]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CompressConfig
from repro.configs import get_smoke_config
from repro.core.calibrate import calibrate_model
from repro.core.compress import compress_model, compression_summary
from repro.data import DataConfig, TokenPipeline
from repro.launch.serve import serve_trace, synthetic_trace
from repro.models import build_model
from repro.serve import ContinuousEngine, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--ratio", type=float, default=0.6)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=4), cfg)

    cal = calibrate_model(model, params, [pipe.get_batch(i) for i in range(2)])
    cparams, reports = compress_model(
        model, params, cal, CompressConfig(method="coala", ratio=args.ratio,
                                           lam=4.0, mu=-1.0))
    s = compression_summary(reports)
    n0 = sum(x.size for x in jax.tree.leaves(params))
    n1 = sum(x.size for x in jax.tree.leaves(cparams))
    print(f"params: {n0/1e6:.2f}M -> {n1/1e6:.2f}M "
          f"(compressed layers kept {s['kept_ratio']:.0%})")

    trace = synthetic_trace(args.requests, cfg.vocab_size,
                            max_new=args.new_tokens)
    for name, p in (("dense", params), ("coala", cparams)):
        eng = ContinuousEngine(model, p, compute_dtype=jnp.float32,
                               cache_dtype=jnp.float32, block_size=8,
                               num_blocks=128, max_running=4)
        m = serve_trace(eng, trace)
        print(f"{name:6s}: {m['requests']} requests  "
              f"{m['requests_per_sec']:.2f} req/s  "
              f"{m['tokens_per_sec']:.1f} tok/s  "
              f"mean TTFT {m['mean_ttft_s']:.3f}s")

    # cross-check: the legacy fixed-batch loop must agree token-for-token
    # under greedy decoding on a uniform batch
    prompt = pipe.get_batch(100)["tokens"][:, :8]
    leg = ServeEngine(model, cparams, compute_dtype=jnp.float32,
                      cache_dtype=jnp.float32)
    cont = ContinuousEngine(model, cparams, compute_dtype=jnp.float32,
                            cache_dtype=jnp.float32, block_size=8,
                            num_blocks=128, max_running=4)
    a = np.asarray(leg.generate(prompt, max_new_tokens=args.new_tokens))
    b = np.asarray(cont.generate(prompt, max_new_tokens=args.new_tokens))
    assert np.array_equal(a, b), "continuous != fixed-batch under greedy"
    print("greedy parity with fixed-batch engine ✓")
    print("done ✓")


if __name__ == "__main__":
    main()
