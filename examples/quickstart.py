"""Quickstart: COALA on a single weight matrix, all three regimes.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (coala_factors, coala_project, eym_truncate,
                        r_from_x, weighted_error)
from repro.core import baselines, theory

key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (256, 192))                 # a "weight matrix"
x = jax.random.normal(jax.random.fold_in(key, 1), (192, 4096))  # activations

# 1) plain context-aware compression (Prop. 1/2, Algorithm 1) -------------
res = coala_factors(w, x, rank=32)
print("rank-32 factors:", res.a.shape, res.b.shape)
print("weighted err COALA :", float(weighted_error(w, res.w_approx, x)))
print("weighted err optimal:", float(theory.optimal_weighted_error(w, x, 32)))
a, b = baselines.plain_svd(w, 32)
print("weighted err plainSVD:", float(weighted_error(w, a @ b, x)))

# 2) big-X regime: stream chunks through TSQR, never materialize X --------
r_factor = r_from_x(x, chunk_tokens=512)               # 8 chunks
res2 = coala_factors(w, r_factor=r_factor, rank=32)
print("streamed == direct:",
      bool(jnp.allclose(res.w_approx, res2.w_approx, atol=1e-4)))

# 3) limited-data regime: k < n with Eq.(5) λ-driven regularization -------
# (rank below rank(X) so the weighted residual — and hence μ — is nonzero)
x_small = jax.random.normal(jax.random.fold_in(key, 2), (192, 24))
res3 = coala_factors(w, x_small, rank=16, lam=4.0)
print(f"limited-data μ selected by Eq.(5): {res3.mu:.4f}")
print("reg solution finite:", bool(jnp.all(jnp.isfinite(res3.w_approx))))
print("Thm-1 distance bound at μ=1e-4:",
      float(theory.thm1_bound(w, x_small, 16, 1e-4)))
