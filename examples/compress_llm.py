"""End-to-end LLM compression: train → calibrate → compress → evaluate → serve.

The paper's §6.1 pipeline at CPU-smoke scale (use --arch/--steps to scale up
on real hardware; every stage is the same code the launcher uses).

  PYTHONPATH=src python examples/compress_llm.py [--arch llama3_1b]
      [--steps 120] [--ratio 0.6] [--methods coala,svd,asvd]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CompressConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.core.calibrate import calibrate_model
from repro.core.compress import compress_model, compression_summary
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.models.common import CPU_CTX
from repro.serve import ServeEngine
from repro.train.train_loop import make_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_1b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ratio", type=float, default=0.6)
    ap.add_argument("--lam", type=float, default=4.0)
    ap.add_argument("--methods", default="coala,svd,asvd,svd_llm")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=8, seed=11), cfg)

    # --- train a base model -------------------------------------------------
    tcfg = TrainConfig(lr=3e-3, warmup_steps=5, total_steps=args.steps,
                       schedule="cosine", compute_dtype="float32")
    state = make_train_state(model, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tcfg, CPU_CTX))
    for i in range(args.steps):
        state, metrics = step(state, pipe.get_batch(i))
        if i % 20 == 0:
            print(f"train step {i}: ce={float(metrics['ce']):.4f}")
    params = state["params"]

    def eval_ce(p):
        return float(np.mean([float(model.loss(p, pipe.get_batch(1000 + i),
                                               compute_dtype=jnp.float32)[0])
                              for i in range(4)]))

    print(f"\nbase model held-out CE: {eval_ce(params):.4f}")

    # --- calibrate: stream activations into per-layer R factors -------------
    cal = calibrate_model(model, params,
                          [pipe.get_batch(2000 + i) for i in range(4)])
    print(f"calibrated {len(cal.streams)} layers "
          f"({next(iter(cal.tokens_seen().values()))} tokens each)")

    # --- compress with each method ------------------------------------------
    best = None
    for method in args.methods.split(","):
        kw = dict(method=method, ratio=args.ratio)
        if method == "coala":
            kw.update(mu=-1.0, lam=args.lam)
        cparams, reports = compress_model(model, params, cal,
                                          CompressConfig(**kw))
        s = compression_summary(reports)
        ce = eval_ce(cparams)
        print(f"{method:10s}: CE={ce:.4f} kept={s['kept_ratio']:.2f} "
              f"layers={s['layers']} mean_rel_err={s['mean_rel_err']:.3f}")
        if best is None or ce < best[1]:
            best = (method, ce, cparams)

    # --- serve the best compressed model ------------------------------------
    method, ce, cparams = best
    eng = ServeEngine(model, cparams, compute_dtype=jnp.float32,
                      cache_dtype=jnp.float32)
    prompt = pipe.get_batch(5000)["tokens"][:2, :8]
    out = eng.generate(prompt, max_new_tokens=12)
    print(f"\nserving compressed model ({method}): generated {out.shape} ✓")
    print(out)


if __name__ == "__main__":
    main()
