"""End-to-end training driver: a ~100M-class model for a few hundred steps
with checkpointing, exact resume, WSD schedule and fault-tolerance hooks.

On the CPU container the default is a reduced width/steps smoke run
(--smoke, on by default); pass --full on real hardware for the 100M config.
Restart the same command after killing it mid-run: it resumes from the
latest checkpoint and reproduces the identical loss curve (step-indexed
deterministic data).

  PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.config import ModelConfig, TrainConfig
from repro.ckpt import CheckpointManager
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.models.common import CPU_CTX
from repro.train.train_loop import make_train_state, make_train_step

FULL_100M = ModelConfig(                # ~100M-param llama-style model
    name="repro-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32000,
    max_seq_len=2048, tie_embeddings=True)

SMOKE = dataclasses.replace(FULL_100M, n_layers=4, d_model=128, n_heads=4,
                            n_kv_heads=2, d_ff=256, vocab_size=512)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = FULL_100M if args.full else SMOKE
    model = build_model(cfg)
    n_params = None
    tcfg = TrainConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                       schedule="wsd", decay_frac=0.15,
                       compute_dtype="float32", microbatches=2)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch, seed=0), cfg)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)

    state = make_train_state(model, tcfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params)")

    start = 0
    if mgr.latest_step() is not None:        # fault-tolerant resume
        state, meta = mgr.restore(state)
        start = meta["step"] + 1
        print(f"resumed from checkpoint at step {meta['step']}")

    step_fn = jax.jit(make_train_step(model, tcfg, CPU_CTX), donate_argnums=0)
    losses = []
    for i in range(start, args.steps):
        state, metrics = step_fn(state, pipe.get_batch(i))
        losses.append(float(metrics["ce"]))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}: ce={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f}")
        if i % args.ckpt_every == 0 and i > start:
            mgr.save(i, state, blocking=False)   # async, off critical path
    mgr.wait()
    mgr.save(args.steps - 1, state)
    uniform = np.log(cfg.vocab_size)
    print(f"\nfinal ce={losses[-1]:.4f} (uniform={uniform:.2f}) "
          f"{'OK: learned' if losses[-1] < uniform - 0.5 else 'WARN: underfit'}")


if __name__ == "__main__":
    main()
