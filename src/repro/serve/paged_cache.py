"""Paged KV-cache block pool for continuous batching.

Vocabulary (shared with docs/serving.md): a *page* is a physical
``block_size``-token slab of the pooled page stores (page 0 is the
reserved trash page); a *block* is the logical unit — a request's token
stream cut into ``block_size``-token runs, its *block table* mapping block
i to the page holding it; a *slot* holds per-request state that does not
grow with tokens (slot ``max_requests`` is the trash slot); an *intern
chain* is the prefix registry's token-exact key structure; a *bucket* is a
padded jit-signature class (batch rows / block envelope).

The pool owns all KV storage as fixed-size token pages plus a per-request
state store, and exposes two read paths:

  * **paged** (the decode hot path): ``paged_cache()`` hands the model the
    page stores *themselves* — token leaves are kept in the leaf's original
    axis order with the (batch, token) axes replaced by (num_blocks,
    block_size), so a stacked-blocks leaf ``(n_rep, B, T, Hkv, hd)`` is
    stored as ``(n_rep, num_blocks, bs, Hkv, hd)`` and slots zero-copy into
    the model's layer scan. The attention layers read the block-table
    indirection directly (``kernels/paged_attention.py``) and write the new
    token into its page in place; ``absorb_paged()`` then just swaps array
    references. No per-step gather or scatter of the cache.
  * **gather** (fallback/oracle): ``gather_batch`` indexes the pool with a
    padded ``(B, nb)`` block-table matrix to assemble exactly the pytree
    ``init_cache`` would have produced, feeding the unmodified jitted
    ``prefill``/``decode_step``; ``scatter_token`` writes back only the page
    each request decoded into.

Which leaf is which is *probed*, not hard-coded: ``CacheLayout`` calls the
model's ``init_cache`` hook at two lengths and two batch sizes and diffs
leaf shapes, so decoder-only, enc-dec, VLM and recurrent layouts all work
unmodified. Token-axis leaves (attention K/V, MLA latents) go to pages;
everything else (mamba/xLSTM recurrent state, whisper cross K/V) lives in a
per-request slot store.

Two trash locations absorb batch padding (shape buckets pad ``B`` and
``nb`` to a closed set of jit signatures): block 0 is the reserved trash
page (table padding points at it), and slot ``max_requests`` is the
reserved trash state slot — padded rows gather/scatter garbage nowhere that
matters, and the per-request causal masks hide whatever they read.

**Prefix caching** (``prefix_cache=True``): blocks are refcounted and a
hash-indexed registry maps *full* blocks of committed tokens to their pages,
so a new request whose prompt shares a block-aligned prefix with anything
served before reuses those pages instead of recomputing them
(``alloc(..., tokens=)`` returns how many prefix tokens were cached).
Registry keys are intern chains — interned ``(parent_prefix,
block_tokens)`` ids — so two prefixes collide only if they are
token-for-token identical and lookups are always token-exact. When a request frees, registered blocks with no
remaining references park in an LRU of *cached* blocks instead of the free
list; allocation evicts from that LRU only under pool pressure. Shared
blocks are never written: writes target the block holding the request's
next position, which ``extend`` guarantees is exclusive by copy-on-write
forking (``fork`` shares a whole table, e.g. best-of-n; the first write to
the shared tail block copies it).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace
from repro.obs.metrics import Registry

_ROOT = -1                      # parent id of a prefix chain's first block


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    batch_axis: int            # axis indexed by request
    token_axis: Optional[int]  # axis that scales with max_len; None => state
    tail: Tuple[int, ...]      # shape with batch (and token) axes removed

    @property
    def blocks_axis(self) -> int:
        """Position of the page axis in the token store (= token axis after
        the batch axis is dropped)."""
        assert self.token_axis is not None
        return self.token_axis - (1 if self.batch_axis < self.token_axis
                                  else 0)

    @property
    def slot_axis(self) -> int:
        """Position of the slot axis in the state store."""
        return self.batch_axis


def _ix(axis: int, idx) -> tuple:
    """Index tuple selecting ``idx`` at ``axis`` (slices before it)."""
    return (slice(None),) * axis + (idx,)


@dataclasses.dataclass(frozen=True)
class CacheLayout:
    """Probed structure of a model's cache pytree."""
    treedef: Any
    specs: Tuple[LeafSpec, ...]
    dtypes: Tuple[Any, ...]

    @staticmethod
    def probe(model, dtype=jnp.bfloat16, probe_len: int = 8) -> "CacheLayout":
        """Diff ``init_cache`` shapes across (batch, len) to classify leaves."""
        shapes = lambda c: [x.shape for x in jax.tree.leaves(c)]
        c11 = model.init_cache(1, probe_len, dtype=dtype)
        s11 = shapes(c11)
        s21 = shapes(model.init_cache(2, probe_len, dtype=dtype))
        s12 = shapes(model.init_cache(1, 2 * probe_len, dtype=dtype))
        specs = []
        for a, b, c in zip(s11, s21, s12):
            b_ax = [i for i, (x, y) in enumerate(zip(a, b)) if x != y]
            t_ax = [i for i, (x, y) in enumerate(zip(a, c)) if x != y]
            assert len(b_ax) == 1, f"ambiguous batch axis: {a} vs {b}"
            assert len(t_ax) <= 1, f"ambiguous token axis: {a} vs {c}"
            token_axis = t_ax[0] if t_ax else None
            drop = {b_ax[0]} | ({token_axis} if token_axis is not None else set())
            tail = tuple(s for i, s in enumerate(a) if i not in drop)
            specs.append(LeafSpec(b_ax[0], token_axis, tail))
        return CacheLayout(jax.tree.structure(c11), tuple(specs),
                           tuple(x.dtype for x in jax.tree.leaves(c11)))


def _token_store_shape(sp: LeafSpec, num_blocks: int, block_size: int):
    ax = sp.blocks_axis
    return sp.tail[:ax] + (num_blocks, block_size) + sp.tail[ax:]


def _state_store_shape(sp: LeafSpec, n_slots: int):
    ax = sp.slot_axis
    return sp.tail[:ax] + (n_slots,) + sp.tail[ax:]


class BlockPool:
    """Free-list block allocator + pooled storage for one model's cache.

    Block 0 and slot ``max_requests`` are reserved (trash, absorb bucket
    padding). ``alloc``/``extend``/``free`` manage the python-side
    accounting; the array ops are jitted and shape-stable in (B, nb).
    """

    def __init__(self, model, *, num_blocks: int, block_size: int,
                 max_requests: int, dtype=jnp.bfloat16,
                 prefix_cache: bool = False, registry=None):
        assert num_blocks >= 2 and block_size >= 1
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.max_requests = max_requests
        self.prefix_cache = prefix_cache
        self.layout = CacheLayout.probe(model, dtype=dtype,
                                        probe_len=max(8, block_size))
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))  # 0 = trash
        self._tables: Dict[int, List[int]] = {}
        self._slots: Dict[int, int] = {}
        self._free_slots: List[int] = list(range(max_requests - 1, -1, -1))
        # --- prefix registry (all empty / inert when prefix_cache=False) ---
        self._ref: Dict[int, int] = {}          # live block -> refcount (>= 1)
        self._intern: Dict[Tuple[int, Tuple[int, ...]], int] = {}
        self._pid_parent: Dict[int, int] = {}   # prefix id -> parent id
        self._next_pid = 0                      # ids never reused (sweeps)
        self._intern_sweep_at = max(8 * num_blocks, 256)
        self._registry: Dict[int, int] = {}     # prefix id -> block holding it
        self._block_pid: Dict[int, int] = {}    # inverse of _registry
        self._lru: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()           # cached refcount-0 blocks
        self._chain: Dict[int, List[int]] = {}  # req -> prefix ids committed
        self.stats: Dict[str, int] = {"cow_copies": 0, "evictions": 0}
        # typed mirrors of ``stats`` plus live-occupancy callback gauges;
        # ``registry`` is the owning engine's (a private one standalone)
        reg = registry if registry is not None else Registry()
        self.registry = reg
        self._c_cow = reg.counter("pool_cow_copies_total",
                                  "copy-on-write block copies")
        self._c_evict = reg.counter("pool_prefix_evictions_total",
                                    "prefix-cache blocks LRU-evicted")
        reg.gauge("pool_free_blocks", "blocks on the free list",
                  fn=lambda: len(self._free))
        reg.gauge("pool_cached_blocks",
                  "evictable prefix-cache blocks (refcount 0)",
                  fn=lambda: len(self._lru))
        # pooled token pages + per-request state store (last slot = trash)
        self.token_store = [
            jnp.zeros(_token_store_shape(sp, num_blocks, block_size), dt)
            for sp, dt in zip(self.layout.specs, self.layout.dtypes)
            if sp.token_axis is not None]
        self.state_store = [
            jnp.zeros(_state_store_shape(sp, max_requests + 1), dt)
            for sp, dt in zip(self.layout.specs, self.layout.dtypes)
            if sp.token_axis is None]

    # ------------------------------------------------------------ accounting
    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1             # block 0 reserved as trash

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        """Registered blocks no live request references (evictable)."""
        return len(self._lru)

    @property
    def available_blocks(self) -> int:
        """Blocks an allocation may claim: truly free + LRU-evictable."""
        return len(self._free) + len(self._lru)

    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def trash_slot(self) -> int:
        return self.max_requests

    def ref_count(self, block: int) -> int:
        return self._ref.get(block, 0)

    def cached_block_ids(self) -> Tuple[int, ...]:
        return tuple(self._lru)

    def free_block_ids(self) -> Tuple[int, ...]:
        return tuple(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.block_size)

    def can_alloc(self, n_tokens: int) -> bool:
        return (self.blocks_for(n_tokens) <= self.available_blocks
                and len(self._free_slots) > 0)

    # ------------------------------------------------------- block lifecycle
    def _incref(self, block: int) -> None:
        if self._ref.get(block, 0) == 0:
            self._lru.pop(block, None)       # cached -> live again
        self._ref[block] = self._ref.get(block, 0) + 1

    def _decref(self, block: int) -> None:
        n = self._ref[block] - 1
        assert n >= 0, f"refcount underflow on block {block}"
        if n:
            self._ref[block] = n
            return
        del self._ref[block]
        if block in self._block_pid:         # registered: park in the LRU
            self._lru[block] = None
        else:
            self._free.append(block)

    def _deregister(self, block: int) -> None:
        pid = self._block_pid.pop(block)
        del self._registry[pid]

    def _take_block(self) -> int:
        """Claim a block: the free list first, then LRU-evict a cached one."""
        if self._free:
            return self._free.pop()
        if self._lru:
            block, _ = self._lru.popitem(last=False)     # least recently freed
            self._deregister(block)
            self.stats["evictions"] += 1
            self._c_evict.inc()
            trace.instant("pool.prefix_evict", block=block)
            return block
        raise MemoryError("block pool exhausted")

    # ------------------------------------------------------- prefix registry
    def _lookup(self, tokens) -> Tuple[List[int], List[int]]:
        """Longest chain of registered full blocks matching ``tokens``
        exactly, capped so at least one token is left to prefill."""
        bs = self.block_size
        max_blocks = (len(tokens) - 1) // bs
        parent, blocks, pids = _ROOT, [], []
        for i in range(max_blocks):
            key = (parent, tuple(int(t) for t in tokens[i * bs:(i + 1) * bs]))
            pid = self._intern.get(key)
            if pid is None or pid not in self._registry:
                break
            blocks.append(self._registry[pid])
            pids.append(pid)
            parent = pid
        return blocks, pids

    def _sweep_intern(self) -> None:
        """Bound the intern table: drop prefix ids that are neither in a
        live request's chain, nor registered, nor an ancestor of either
        (ancestors keep evicted-then-recommitted chains revivable under
        their original ids). Without this the table would grow by one entry
        per distinct block ever served."""
        keep = set(self._registry)
        for chain in self._chain.values():
            keep.update(chain)
        for pid in list(keep):
            p = self._pid_parent.get(pid, _ROOT)
            while p != _ROOT and p not in keep:
                keep.add(p)
                p = self._pid_parent.get(p, _ROOT)
        for key, pid in list(self._intern.items()):
            if pid not in keep:
                del self._intern[key]
                self._pid_parent.pop(pid, None)
        # re-arm so a legitimately large working set doesn't sweep per commit
        self._intern_sweep_at = max(2 * len(self._intern),
                                    8 * self.num_blocks, 256)

    def probe_prefix(self, tokens) -> int:
        """Cached-prefix tokens a lookup would hit right now (no acquire)."""
        if not self.prefix_cache or tokens is None:
            return 0
        return len(self._lookup(tokens)[0]) * self.block_size

    def commit(self, req_id: int, tokens) -> None:
        """Register the request's newly completed full blocks of ``tokens``
        (its committed prompt+generated stream) in the prefix registry."""
        if not self.prefix_cache or req_id not in self._tables:
            return
        bs = self.block_size
        table = self._tables[req_id]
        chain = self._chain.setdefault(req_id, [])
        n_full = min(len(tokens) // bs, len(table))
        while len(chain) < n_full:
            i = len(chain)
            parent = chain[-1] if chain else _ROOT
            key = (parent, tuple(int(t) for t in tokens[i * bs:(i + 1) * bs]))
            pid = self._intern.get(key)
            if pid is None:
                pid = self._next_pid
                self._next_pid += 1
                self._intern[key] = pid
                self._pid_parent[pid] = parent
                if len(self._intern) > self._intern_sweep_at:
                    self._sweep_intern()
            chain.append(pid)
            # first committer wins; duplicates stay unregistered and return
            # to the free list when their request ends
            if pid not in self._registry and table[i] not in self._block_pid:
                self._registry[pid] = table[i]
                self._block_pid[table[i]] = pid

    def alloc(self, req_id: int, n_tokens: int, tokens=None) -> int:
        """Reserve blocks covering ``n_tokens`` and a state slot.

        With ``prefix_cache`` and the request's token stream in ``tokens``,
        the longest registered block-aligned prefix is reused (refcounted)
        instead of freshly allocated. Returns the number of cached prefix
        tokens (0 without caching); the caller prefills only the suffix.
        """
        assert req_id not in self._tables, f"request {req_id} already allocated"
        hit_blocks: List[int] = []
        hit_pids: List[int] = []
        if self.prefix_cache and tokens is not None and len(tokens) > 1:
            hit_blocks, hit_pids = self._lookup(tokens)
        need = self.blocks_for(n_tokens) - len(hit_blocks)
        assert need >= 0
        for b in hit_blocks:                 # pin hits before any eviction
            self._incref(b)
        if need > self.available_blocks or not self._free_slots:
            for b in hit_blocks:
                self._decref(b)
            raise MemoryError(
                f"pool exhausted: need {need} blocks / 1 slot, have "
                f"{self.available_blocks} blocks / "
                f"{len(self._free_slots)} slots")
        blks = [self._take_block() for _ in range(need)]
        self._zero(blks)
        for b in blks:
            self._ref[b] = 1
        self._tables[req_id] = hit_blocks + blks
        self._slots[req_id] = self._free_slots.pop()
        self._chain[req_id] = list(hit_pids)
        return len(hit_blocks) * self.block_size

    def extend(self, req_id: int, n_tokens: int, *,
               write_start: Optional[int] = None) -> None:
        """Grow the request's table to cover ``n_tokens`` total tokens and
        guarantee the written span is exclusively owned (copy-on-write if
        shared with another request).

        By default only the block holding token ``n_tokens - 1`` is made
        writable (single-token decode). ``write_start`` widens the COW
        guarantee to every block covering ``[write_start, n_tokens - 1]`` —
        the speculative draft/verify paths write an L-token run that can
        begin mid-block inside a fork-shared page."""
        table = self._tables[req_id]
        need = self.blocks_for(n_tokens) - len(table)
        if need > self.available_blocks:
            raise MemoryError(f"pool exhausted extending request {req_id}")
        if need > 0:
            blks = [self._take_block() for _ in range(need)]
            self._zero(blks)
            for b in blks:
                self._ref[b] = 1
            table.extend(blks)
        lo = n_tokens - 1 if write_start is None else \
            max(0, min(write_start, n_tokens - 1))
        for i in range(lo // self.block_size,
                       (n_tokens - 1) // self.block_size + 1):
            self._ensure_writable(req_id, i * self.block_size
                                  if i * self.block_size > lo else lo)

    def truncate(self, req_id: int, n_tokens: int) -> None:
        """Roll back the request's table to cover only ``n_tokens`` tokens,
        releasing blocks past that point (speculative-decode rejection: the
        uncommitted tail pages a rejected draft run wrote are dropped; a
        registered or fork-shared block is decref'd, not clobbered)."""
        table = self._tables[req_id]
        keep = self.blocks_for(n_tokens)
        while len(table) > keep:
            self._decref(table.pop())
        chain = self._chain.get(req_id)
        if chain is not None and len(chain) > len(table):
            del chain[len(table):]

    def _ensure_writable(self, req_id: int, pos: int) -> None:
        """Copy-on-write: the block containing ``pos`` must have refcount 1.
        Only uncommitted (partial) blocks are ever written, so the registry
        is never invalidated by a write."""
        table = self._tables[req_id]
        i = pos // self.block_size
        blk = table[i]
        if self._ref[blk] <= 1:
            return
        with trace.span("pool.cow_copy", req_id=req_id, block=blk):
            new = self._take_block()
            if self.token_store:
                self.token_store = _copy_block(
                    tuple(self.layout.specs), self.token_store,
                    jnp.int32(blk), jnp.int32(new))
            self._ref[new] = 1
            self._decref(blk)
            table[i] = new
            self.stats["cow_copies"] += 1
            self._c_cow.inc()

    def fork(self, parent_id: int, child_id: int) -> None:
        """Share the parent's whole table with ``child_id`` (copy-on-write:
        the first divergent write mid-block copies that block) and duplicate
        its recurrent-state slot."""
        assert child_id not in self._tables
        if not self._free_slots:
            raise MemoryError("no free state slot to fork into")
        table = list(self._tables[parent_id])
        for b in table:
            self._incref(b)
        self._tables[child_id] = table
        self._slots[child_id] = self._free_slots.pop()
        self._chain[child_id] = list(self._chain.get(parent_id, []))
        if self.state_store:
            self.state_store = _copy_state_slot(
                tuple(self.layout.specs), self.state_store,
                jnp.int32(self._slots[parent_id]),
                jnp.int32(self._slots[child_id]))

    def _zero(self, blks: List[int]) -> None:
        # reused blocks must read as zeros, not stale KV from a freed request.
        # The id count pads to a power of two (trash page absorbs the extra
        # writes) so the zeroing jit keeps a closed signature set that
        # ``warm()`` can pre-compile instead of recompiling per alloc size.
        if blks and self.token_store:
            n = 1 << max(len(blks) - 1, 0).bit_length()
            ids = list(blks) + [0] * (n - len(blks))
            self.token_store = _zero_blocks(tuple(self.layout.specs),
                                            self.token_store,
                                            jnp.asarray(ids, jnp.int32))

    def warm(self, max_blocks: int) -> None:
        """Pre-compile the pool's own jitted maintenance ops — block zeroing
        at every padded id-count signature up to ``max_blocks`` and the
        copy-on-write block copy — against the trash page, so none of them
        compiles on a request's critical path after ``ContinuousEngine.
        warmup()``."""
        if not self.token_store:
            return
        n = 1
        while True:
            self.token_store = _zero_blocks(tuple(self.layout.specs),
                                            self.token_store,
                                            jnp.zeros((n,), jnp.int32))
            if n >= max(max_blocks, 1):
                break
            n *= 2
        # trash copied onto itself: same signature as a real COW copy
        self.token_store = _copy_block(tuple(self.layout.specs),
                                       self.token_store,
                                       jnp.int32(0), jnp.int32(0))

    def free(self, req_id: int) -> None:
        for b in self._tables.pop(req_id):
            self._decref(b)
        self._free_slots.append(self._slots.pop(req_id))
        self._chain.pop(req_id, None)

    def table(self, req_id: int) -> List[int]:
        return list(self._tables[req_id])

    def slot(self, req_id: int) -> int:
        return self._slots[req_id]

    def max_table_blocks(self, req_ids) -> int:
        return max((len(self._tables[r]) for r in req_ids), default=0)

    def padded_tables(self, req_ids, *, rows: Optional[int] = None,
                      blocks: Optional[int] = None) -> jnp.ndarray:
        """(rows, blocks) int32 block tables. Ragged rows are padded with
        the trash block; extra rows (batch-bucket padding) are all-trash."""
        nb = self.max_table_blocks(req_ids)
        nb = max(blocks or nb, nb)
        b = max(rows or len(req_ids), len(req_ids))
        rows_ = [self._tables[r] + [0] * (nb - len(self._tables[r]))
                 for r in req_ids]
        rows_ += [[0] * nb] * (b - len(req_ids))
        return jnp.asarray(rows_, jnp.int32)

    def slots(self, req_ids, *, rows: Optional[int] = None) -> jnp.ndarray:
        s = [self._slots[r] for r in req_ids]
        b = max(rows or len(req_ids), len(req_ids))
        s += [self.trash_slot] * (b - len(req_ids))
        return jnp.asarray(s, jnp.int32)

    # ------------------------------------------------------ paged (hot path)
    def paged_cache(self, req_ids, *, rows: Optional[int] = None):
        """Cache pytree for the paged decode path: token leaves are the page
        stores themselves (original axis order — zero copy), state leaves
        are gathered per-slot for the (padded) batch."""
        state = _gather_state(tuple(self.layout.specs), self.state_store,
                              self.slots(req_ids, rows=rows))
        leaves, ti, si = [], 0, 0
        for sp in self.layout.specs:
            if sp.token_axis is None:
                leaves.append(state[si])
                si += 1
            else:
                leaves.append(self.token_store[ti])
                ti += 1
        return jax.tree.unflatten(self.layout.treedef, leaves)

    def absorb_paged(self, req_ids, cache, *, rows: Optional[int] = None) -> None:
        """Take back the cache returned by a paged decode step: token leaves
        ARE the updated page stores (swap references); state leaves are
        scattered back into their slots (padding rows hit the trash slot)."""
        token, state = [], []
        for sp, leaf in zip(self.layout.specs, jax.tree.leaves(cache)):
            (state if sp.token_axis is None else token).append(leaf)
        self.token_store = token
        if state:
            self.state_store = _scatter_state(
                tuple(self.layout.specs), self.state_store, tuple(state),
                self.slots(req_ids, rows=rows))

    # --------------------------------------------------- gather (oracle path)
    def gather_batch(self, req_ids, *, rows: Optional[int] = None,
                     blocks: Optional[int] = None):
        """Assemble the contiguous batched cache pytree for ``req_ids``.

        Returns a pytree identical in structure to
        ``model.init_cache(B, nb * block_size)`` — directly consumable by the
        jitted prefill/decode functions. ``rows``/``blocks`` pad the batch
        and page envelope to bucket sizes (padding rows read trash).
        """
        tables = self.padded_tables(req_ids, rows=rows, blocks=blocks)
        slots = self.slots(req_ids, rows=rows)
        leaves = _gather(tuple(self.layout.specs), self.block_size,
                         self.token_store, self.state_store, tables, slots)
        return jax.tree.unflatten(self.layout.treedef, leaves)

    def scatter_prefill(self, req_ids, cache, n_tokens: int) -> None:
        """Write the first ``n_tokens`` positions of a freshly prefilled
        contiguous cache (plus all state leaves) back into the pool."""
        tables = self.padded_tables(req_ids)
        nb_used = self.blocks_for(n_tokens)
        self.token_store, self.state_store = _scatter_prefill(
            tuple(self.layout.specs), self.block_size, nb_used,
            self.token_store, self.state_store,
            tuple(jax.tree.leaves(cache)), tables, self.slots(req_ids))

    def scatter_suffix(self, req_ids, cache, starts, lens, *,
                       rows: Optional[int] = None,
                       blocks: Optional[int] = None) -> None:
        """Write back only the blocks each request's suffix prefill touched:
        row ``i`` scatters blocks covering token range
        ``[starts[i], starts[i] + lens[i])`` (plus all state leaves).

        Blocks outside that range — shared prefix blocks below it, envelope
        padding above it — are redirected to the trash page, so a cached
        prefix another request references is never rewritten. ``rows`` and
        ``blocks`` pad to the same bucketed (B, nb) envelope the cache was
        gathered with, keeping the jit signature closed."""
        tables = np.asarray(self.padded_tables(req_ids, rows=rows,
                                               blocks=blocks))
        b, nb = tables.shape
        lo = np.zeros((b,), np.int64)
        hi = np.zeros((b,), np.int64)
        lo[:len(req_ids)] = np.asarray(starts) // self.block_size
        hi[:len(req_ids)] = [self.blocks_for(s + l) if l else 0
                             for s, l in zip(starts, lens)]
        j = np.arange(nb)
        masked = np.where((j[None, :] >= lo[:, None])
                          & (j[None, :] < hi[:, None]), tables, 0)
        self.token_store, self.state_store = _scatter_prefill(
            tuple(self.layout.specs), self.block_size, nb,
            self.token_store, self.state_store,
            tuple(jax.tree.leaves(cache)),
            jnp.asarray(masked, jnp.int32), self.slots(req_ids, rows=rows))

    def scatter_token(self, req_ids, cache, positions, *,
                      rows: Optional[int] = None,
                      blocks: Optional[int] = None) -> None:
        """Write back the single page each request decoded into (the block
        containing ``positions[i]``) plus updated state leaves. ``positions``
        must already be padded to ``rows`` (padding rows write trash);
        ``blocks`` pads the table width to the same bucket the cache was
        gathered with, keeping this op's jit signature bucketed too."""
        tables = self.padded_tables(req_ids, rows=rows, blocks=blocks)
        self.token_store, self.state_store = _scatter_token(
            tuple(self.layout.specs), self.block_size,
            self.token_store, self.state_store,
            tuple(jax.tree.leaves(cache)), tables,
            self.slots(req_ids, rows=rows),
            jnp.asarray(positions, jnp.int32))


# ---------------------------------------------------------------------------
# jitted pool <-> batch converters
#
# The store arguments of the in-place update ops are donated so XLA reuses
# the pool buffers instead of copying the whole pool every step; the pool
# immediately replaces its references with the returned arrays.
#
# Token stores keep the leaf's original axis order, so indexing happens at
# ``spec.blocks_axis`` (resp. ``spec.slot_axis``) rather than axis 0; the
# only data ever transposed is the gathered batch-sized slice, never a pool.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _zero_blocks(specs, token_store, ids):
    token_specs = [sp for sp in specs if sp.token_axis is not None]
    return [s.at[_ix(sp.blocks_axis, ids)].set(0)
            for sp, s in zip(token_specs, token_store)]


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _copy_block(specs, token_store, src, dst):
    """Copy-on-write: duplicate page ``src`` into ``dst`` on every leaf."""
    token_specs = [sp for sp in specs if sp.token_axis is not None]
    return [s.at[_ix(sp.blocks_axis, dst)].set(s[_ix(sp.blocks_axis, src)])
            for sp, s in zip(token_specs, token_store)]


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _copy_state_slot(specs, state_store, src, dst):
    """Fork: duplicate the per-request state slot ``src`` into ``dst``."""
    state_specs = [sp for sp in specs if sp.token_axis is None]
    return [s.at[_ix(sp.slot_axis, dst)].set(s[_ix(sp.slot_axis, src)])
            for sp, s in zip(state_specs, state_store)]


@functools.partial(jax.jit, static_argnums=(0,))
def _gather_state(specs, state_store, slots):
    """slots: (B,). Returns state leaves in original axis order."""
    out, si = [], 0
    for sp in specs:
        if sp.token_axis is not None:
            continue
        out.append(jnp.take(state_store[si], slots, axis=sp.slot_axis))
        si += 1
    return out


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _scatter_state(specs, state_store, state_leaves, slots):
    new_state, si = list(state_store), 0
    for sp in specs:
        if sp.token_axis is not None:
            continue
        leaf = state_leaves[si]
        new_state[si] = new_state[si].at[_ix(sp.slot_axis, slots)].set(
            leaf.astype(new_state[si].dtype))
        si += 1
    return new_state


@functools.partial(jax.jit, static_argnums=(0, 1))
def _gather(specs, block_size, token_store, state_store, tables, slots):
    """tables: (B, nb); slots: (B,). Returns leaves in treedef order."""
    b, nb = tables.shape
    out, ti, si = [], 0, 0
    for sp in specs:
        if sp.token_axis is None:
            out.append(jnp.take(state_store[si], slots, axis=sp.slot_axis))
            si += 1
            continue
        ax = sp.blocks_axis
        g = jnp.take(token_store[ti], tables, axis=ax)   # pre+(B,nb,bs)+post
        g = g.reshape(g.shape[:ax] + (b, nb * block_size) + g.shape[ax + 3:])
        # batch now sits where the page axis was; restore the original order
        out.append(jnp.moveaxis(g, ax, sp.batch_axis))
        ti += 1
    return out


@functools.partial(jax.jit, static_argnums=(0, 1, 2), donate_argnums=(3, 4))
def _scatter_prefill(specs, block_size, nb_used, token_store, state_store,
                     cache_leaves, tables, slots):
    b = tables.shape[0]
    new_token, new_state = list(token_store), list(state_store)
    ti, si = 0, 0
    for sp, leaf in zip(specs, cache_leaves):
        if sp.token_axis is None:
            new_state[si] = new_state[si].at[_ix(sp.slot_axis, slots)].set(
                leaf.astype(new_state[si].dtype))
            si += 1
            continue
        ax = sp.blocks_axis
        t_used = nb_used * block_size
        blk = jnp.take(leaf, jnp.arange(t_used), axis=sp.token_axis)
        blk = blk.reshape(blk.shape[:sp.token_axis] + (nb_used, block_size)
                          + blk.shape[sp.token_axis + 1:])
        # move batch to just before the page axis (splitting the token axis
        # shifted it by one when it followed the token axis)
        b_src = sp.batch_axis + (1 if sp.batch_axis > sp.token_axis else 0)
        blk = jnp.moveaxis(blk, b_src, ax)               # pre+(B,nb,bs)+post
        ids = tables[:, :nb_used]                        # (B, nb_used)
        new_token[ti] = new_token[ti].at[_ix(ax, ids)].set(
            blk.astype(new_token[ti].dtype))
        ti += 1
    return new_token, new_state


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=(2, 3))
def _scatter_token(specs, block_size, token_store, state_store,
                   cache_leaves, tables, slots, positions):
    """Write back only the page containing ``positions[i]`` per request."""
    blk_idx = positions // block_size                    # (B,)
    blk_ids = jnp.take_along_axis(tables, blk_idx[:, None], axis=1)[:, 0]
    new_token, new_state = list(token_store), list(state_store)
    ti, si = 0, 0
    for sp, leaf in zip(specs, cache_leaves):
        if sp.token_axis is None:
            new_state[si] = new_state[si].at[_ix(sp.slot_axis, slots)].set(
                leaf.astype(new_state[si].dtype))
            si += 1
            continue
        arr = jnp.moveaxis(leaf, (sp.batch_axis, sp.token_axis), (0, 1))
        slab = jax.vmap(
            lambda a, i: jax.lax.dynamic_slice_in_dim(
                a, i * block_size, block_size, axis=0)
        )(arr, blk_idx)                                  # (B, bs, *tail)
        ax = sp.blocks_axis
        slab = jnp.moveaxis(slab, (0, 1), (ax, ax + 1))  # pre+(B,bs)+post
        new_token[ti] = new_token[ti].at[_ix(ax, blk_ids)].set(
            slab.astype(new_token[ti].dtype))
        ti += 1
    return new_token, new_state
