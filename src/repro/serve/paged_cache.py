"""Paged KV-cache block pool for continuous batching.

The pool owns all KV storage as fixed-size *token blocks* plus a per-request
*state* store, and hands the engine contiguous padded views on demand:

  * token-axis cache leaves (attention K/V, MLA latents) are stored as
    ``(num_blocks, block_size, *tail)`` and addressed through per-request
    block tables (free-list allocator, alloc/extend/free at block
    granularity) — no request ever reserves ``max_len`` slots up front;
  * per-request state leaves (mamba/xLSTM recurrent state, whisper cross
    K/V — anything whose shape does not scale with ``max_len``) live in a
    ``(max_requests, *tail)`` slot store.

Which leaf is which is *probed*, not hard-coded: ``CacheLayout`` calls the
model's ``init_cache`` hook at two lengths and two batch sizes and diffs leaf
shapes, so the same pool works for decoder-only, enc-dec and VLM layouts
without per-model plumbing.

The read path is gather-based: ``gather_batch`` indexes the pool with a
padded ``(B, nb)`` block-table matrix to assemble exactly the pytree
``init_cache`` would have produced for a contiguous batch, which feeds the
existing jitted ``prefill``/``decode_step`` unchanged. ``scatter_token``
writes back only the block each request just decoded into (O(block_size)
per step, not O(T)). Block 0 is a reserved trash block: table padding points
at it, so ragged batches scatter garbage nowhere that matters, and the
causal mask (per-request positions) hides whatever is gathered from it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    batch_axis: int            # axis indexed by request
    token_axis: Optional[int]  # axis that scales with max_len; None => state
    tail: Tuple[int, ...]      # shape with batch (and token) axes removed


@dataclasses.dataclass(frozen=True)
class CacheLayout:
    """Probed structure of a model's cache pytree."""
    treedef: Any
    specs: Tuple[LeafSpec, ...]
    dtypes: Tuple[Any, ...]

    @staticmethod
    def probe(model, dtype=jnp.bfloat16, probe_len: int = 8) -> "CacheLayout":
        """Diff ``init_cache`` shapes across (batch, len) to classify leaves."""
        shapes = lambda c: [x.shape for x in jax.tree.leaves(c)]
        c11 = model.init_cache(1, probe_len, dtype=dtype)
        s11 = shapes(c11)
        s21 = shapes(model.init_cache(2, probe_len, dtype=dtype))
        s12 = shapes(model.init_cache(1, 2 * probe_len, dtype=dtype))
        specs = []
        for a, b, c in zip(s11, s21, s12):
            b_ax = [i for i, (x, y) in enumerate(zip(a, b)) if x != y]
            t_ax = [i for i, (x, y) in enumerate(zip(a, c)) if x != y]
            assert len(b_ax) == 1, f"ambiguous batch axis: {a} vs {b}"
            assert len(t_ax) <= 1, f"ambiguous token axis: {a} vs {c}"
            token_axis = t_ax[0] if t_ax else None
            drop = {b_ax[0]} | ({token_axis} if token_axis is not None else set())
            tail = tuple(s for i, s in enumerate(a) if i not in drop)
            specs.append(LeafSpec(b_ax[0], token_axis, tail))
        return CacheLayout(jax.tree.structure(c11), tuple(specs),
                           tuple(x.dtype for x in jax.tree.leaves(c11)))


def _to_pool_order(leaf, spec: LeafSpec):
    """(… batch … token …) -> (batch, token, *tail) for token leaves,
    (batch, *tail) for state leaves."""
    if spec.token_axis is None:
        return jnp.moveaxis(leaf, spec.batch_axis, 0)
    return jnp.moveaxis(leaf, (spec.batch_axis, spec.token_axis), (0, 1))


def _from_pool_order(arr, spec: LeafSpec):
    if spec.token_axis is None:
        return jnp.moveaxis(arr, 0, spec.batch_axis)
    return jnp.moveaxis(arr, (0, 1), (spec.batch_axis, spec.token_axis))


class BlockPool:
    """Free-list block allocator + pooled storage for one model's cache.

    Block 0 is reserved (trash). ``alloc``/``extend``/``free`` manage the
    python-side accounting; the array ops (``gather_batch``, ``scatter_*``)
    are jitted and shape-stable in (B, nb).
    """

    def __init__(self, model, *, num_blocks: int, block_size: int,
                 max_requests: int, dtype=jnp.bfloat16):
        assert num_blocks >= 2 and block_size >= 1
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.max_requests = max_requests
        self.layout = CacheLayout.probe(model, dtype=dtype,
                                        probe_len=max(8, block_size))
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))  # 0 = trash
        self._tables: Dict[int, List[int]] = {}
        self._slots: Dict[int, int] = {}
        self._free_slots: List[int] = list(range(max_requests - 1, -1, -1))
        # pooled token storage + per-request state store
        self.token_store = [
            jnp.zeros((num_blocks, block_size) + sp.tail, dt)
            for sp, dt in zip(self.layout.specs, self.layout.dtypes)
            if sp.token_axis is not None]
        self.state_store = [
            jnp.zeros((max_requests,) + sp.tail, dt)
            for sp, dt in zip(self.layout.specs, self.layout.dtypes)
            if sp.token_axis is None]

    # ------------------------------------------------------------ accounting
    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1             # block 0 reserved as trash

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    def blocks_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.block_size)

    def can_alloc(self, n_tokens: int) -> bool:
        return (self.blocks_for(n_tokens) <= len(self._free)
                and len(self._free_slots) > 0)

    def alloc(self, req_id: int, n_tokens: int) -> None:
        """Reserve blocks covering ``n_tokens`` and a state slot."""
        assert req_id not in self._tables, f"request {req_id} already allocated"
        need = self.blocks_for(n_tokens)
        if need > len(self._free) or not self._free_slots:
            raise MemoryError(
                f"pool exhausted: need {need} blocks / 1 slot, have "
                f"{len(self._free)} blocks / {len(self._free_slots)} slots")
        blks = [self._free.pop() for _ in range(need)]
        self._zero(blks)
        self._tables[req_id] = blks
        self._slots[req_id] = self._free_slots.pop()

    def extend(self, req_id: int, n_tokens: int) -> None:
        """Grow the request's table to cover ``n_tokens`` total tokens."""
        table = self._tables[req_id]
        need = self.blocks_for(n_tokens) - len(table)
        if need > len(self._free):
            raise MemoryError(f"pool exhausted extending request {req_id}")
        if need > 0:
            blks = [self._free.pop() for _ in range(need)]
            self._zero(blks)
            table.extend(blks)

    def _zero(self, blks: List[int]) -> None:
        # reused blocks must read as zeros, not stale KV from a freed request
        if blks and self.token_store:
            self.token_store = _zero_blocks(self.token_store,
                                            jnp.asarray(blks, jnp.int32))

    def free(self, req_id: int) -> None:
        self._free.extend(self._tables.pop(req_id))
        self._free_slots.append(self._slots.pop(req_id))

    def table(self, req_id: int) -> List[int]:
        return list(self._tables[req_id])

    def slot(self, req_id: int) -> int:
        return self._slots[req_id]

    def padded_tables(self, req_ids) -> jnp.ndarray:
        """(B, nb) int32 block tables, ragged rows padded with the trash
        block; nb is the max table length over the batch."""
        nb = max(len(self._tables[r]) for r in req_ids)
        rows = [self._tables[r] + [0] * (nb - len(self._tables[r]))
                for r in req_ids]
        return jnp.asarray(rows, jnp.int32)

    def slots(self, req_ids) -> jnp.ndarray:
        return jnp.asarray([self._slots[r] for r in req_ids], jnp.int32)

    # ------------------------------------------------------------- array ops
    def gather_batch(self, req_ids):
        """Assemble the contiguous batched cache pytree for ``req_ids``.

        Returns a pytree identical in structure to
        ``model.init_cache(B, nb * block_size)`` — directly consumable by the
        jitted prefill/decode functions.
        """
        tables = self.padded_tables(req_ids)
        slots = self.slots(req_ids)
        leaves = _gather(tuple(self.layout.specs), self.block_size,
                         self.token_store, self.state_store, tables, slots)
        return jax.tree.unflatten(self.layout.treedef, leaves)

    def scatter_prefill(self, req_ids, cache, n_tokens: int) -> None:
        """Write the first ``n_tokens`` positions of a freshly prefilled
        contiguous cache (plus all state leaves) back into the pool."""
        tables = self.padded_tables(req_ids)
        nb_used = self.blocks_for(n_tokens)
        self.token_store, new_state = _scatter_prefill(
            tuple(self.layout.specs), self.block_size, nb_used,
            self.token_store, self.state_store,
            tuple(jax.tree.leaves(cache)), tables, self.slots(req_ids))
        self.state_store = new_state

    def scatter_token(self, req_ids, cache, positions) -> None:
        """Write back the single block each request decoded into (the block
        containing ``positions[i]``) plus updated state leaves."""
        tables = self.padded_tables(req_ids)
        self.token_store, self.state_store = _scatter_token(
            tuple(self.layout.specs), self.block_size,
            self.token_store, self.state_store,
            tuple(jax.tree.leaves(cache)), tables, self.slots(req_ids),
            jnp.asarray(positions, jnp.int32))


# ---------------------------------------------------------------------------
# jitted pool <-> contiguous-batch converters
#
# The store arguments of the in-place update ops are donated so XLA reuses
# the pool buffers instead of copying the whole pool every step; the pool
# immediately replaces its references with the returned arrays.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0,))
def _zero_blocks(token_store, ids):
    return [s.at[ids].set(0) for s in token_store]


@functools.partial(jax.jit, static_argnums=(0, 1))
def _gather(specs, block_size, token_store, state_store, tables, slots):
    """tables: (B, nb); slots: (B,). Returns leaves in treedef order."""
    b, nb = tables.shape
    out, ti, si = [], 0, 0
    for sp in specs:
        if sp.token_axis is None:
            arr = state_store[si][slots]                     # (B, *tail)
            si += 1
        else:
            g = token_store[ti][tables]                      # (B, nb, bs, *tail)
            arr = g.reshape((b, nb * block_size) + g.shape[3:])
            ti += 1
        out.append(_from_pool_order(arr, sp))
    return out


@functools.partial(jax.jit, static_argnums=(0, 1, 2), donate_argnums=(3, 4))
def _scatter_prefill(specs, block_size, nb_used, token_store, state_store,
                     cache_leaves, tables, slots):
    b = tables.shape[0]
    new_token, new_state = list(token_store), list(state_store)
    ti, si = 0, 0
    for sp, leaf in zip(specs, cache_leaves):
        arr = _to_pool_order(leaf, sp)                       # (B, T, *tail)
        if sp.token_axis is None:
            new_state[si] = new_state[si].at[slots].set(
                arr.astype(new_state[si].dtype))
            si += 1
            continue
        t_used = nb_used * block_size
        blk = arr[:, :t_used].reshape(
            (b, nb_used, block_size) + arr.shape[2:])
        ids = tables[:, :nb_used]                            # (B, nb_used)
        new_token[ti] = new_token[ti].at[ids].set(
            blk.astype(new_token[ti].dtype))
        ti += 1
    return new_token, new_state


@functools.partial(jax.jit, static_argnums=(0, 1), donate_argnums=(2, 3))
def _scatter_token(specs, block_size, token_store, state_store,
                   cache_leaves, tables, slots, positions):
    """Write back only the block containing ``positions[i]`` per request."""
    blk_idx = positions // block_size                        # (B,)
    blk_ids = jnp.take_along_axis(tables, blk_idx[:, None], axis=1)[:, 0]
    new_token, new_state = list(token_store), list(state_store)
    ti, si = 0, 0
    for sp, leaf in zip(specs, cache_leaves):
        arr = _to_pool_order(leaf, sp)                       # (B, T, *tail)
        if sp.token_axis is None:
            new_state[si] = new_state[si].at[slots].set(
                arr.astype(new_state[si].dtype))
            si += 1
            continue
        slab = jax.vmap(
            lambda a, i: jax.lax.dynamic_slice_in_dim(
                a, i * block_size, block_size, axis=0)
        )(arr, blk_idx)                                      # (B, bs, *tail)
        new_token[ti] = new_token[ti].at[blk_ids].set(
            slab.astype(new_token[ti].dtype))
        ti += 1
    return new_token, new_state
