"""Continuous-batching scheduler: request queue + admission control.

Requests join the running decode batch the moment a state slot and enough
cache blocks are available — no waiting for a synchronized batch to drain
— and are evicted (their pages freed, or parked in the prefix cache's LRU
if registered) the step they hit max-tokens/EOS. Admission counts
LRU-evictable cached pages as capacity, since the pool reclaims them on
demand. When the pool runs dry mid-decode the youngest running request is
preempted: its pages are freed and it is pushed back to the front of the
queue, to be re-prefilled over prompt + tokens-generated-so-far once
memory frees up (generation is deterministic per request, so a preempted
greedy request resumes on the same trajectory — and its own committed
blocks are prefix-cache hits). Vocabulary and data flow: docs/serving.md.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, List, Optional

import numpy as np

from repro.obs import trace
from repro.obs.metrics import LATENCY_BUCKETS, Registry

WAITING = "waiting"
RUNNING = "running"
FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request plus its lifecycle metrics."""
    req_id: int
    prompt: np.ndarray                       # (T0,) int32
    max_new_tokens: int
    temperature: float = 0.0
    seed: int = 0
    eos_id: Optional[int] = None
    extras: Optional[dict] = None            # frames / vision_embeds, (1, ...)
    vis_offset: int = 0                      # vlm: vision-prefix cache positions
    cacheable: bool = False                  # eligible for prefix caching /
    #                                          batched suffix prefill (set by
    #                                          the engine: no extras, text-only
    #                                          cache positions)
    stream_callback: Optional[Callable] = None  # per-token StreamEvent sink,
    #                                          run on the detokenize worker
    #                                          (or inline with async_detok off)
    text: str = ""                           # detokenized output accumulated
    #                                          by the detokenize pipeline
    state: str = WAITING
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    cache_len: int = 0                       # logical positions written to cache
    admit_seq: int = -1                      # order of (latest) admission
    preemptions: int = 0
    arrival_time: float = dataclasses.field(default_factory=time.perf_counter)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    spec_proposed: int = 0                   # draft tokens proposed for this
    spec_accepted: int = 0                   # request / accepted by the target

    @property
    def done(self) -> bool:
        if len(self.out_tokens) >= self.max_new_tokens:
            return True
        return (self.eos_id is not None and self.out_tokens
                and self.out_tokens[-1] == self.eos_id)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def prefill_tokens(self) -> np.ndarray:
        """Tokens to prefill over: the prompt, plus — after a preemption —
        everything already generated."""
        if not self.out_tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out_tokens, np.int32)])

    def cache_budget(self) -> int:
        """Worst-case cache positions this request may still occupy."""
        remaining = self.max_new_tokens - len(self.out_tokens)
        return (self.vis_offset + len(self.prompt) + len(self.out_tokens)
                + max(remaining, 0))


class Scheduler:
    """FIFO admission against pool capacity and a running-slot cap."""

    def __init__(self, pool, max_running: int = 8,
                 registry: Optional[Registry] = None,
                 headroom_tokens: int = 0, flight=None):
        self.pool = pool
        self.max_running = max_running
        # optional obs.flight.FlightRecorder: admission, preemption and
        # eviction land here so a postmortem shows the scheduling history
        self.flight = flight
        # extra cache positions every running request may transiently write
        # past its budget (speculative decoding: a verify round can land up
        # to spec_k uncommitted tail tokens before rollback)
        self.headroom_tokens = headroom_tokens
        self.waiting: Deque[Request] = collections.deque()
        self.running: List[Request] = []
        self._admit_seq = 0
        # queue observability (docs/observability.md): depth reads the live
        # deque via a callback gauge; wait is observed at admission from the
        # request's arrival timestamp
        reg = registry if registry is not None else Registry()
        self.registry = reg
        self._g_queue_depth = reg.gauge(
            "serve_queue_depth", "requests waiting for admission",
            fn=lambda: len(self.waiting))
        self._h_queue_wait = reg.histogram(
            "serve_queue_wait_seconds", LATENCY_BUCKETS,
            "arrival -> (latest) admission wait")
        self._c_admitted = reg.counter(
            "serve_requests_admitted_total",
            "admissions (re-admission after preemption counts again)")
        self._c_preemptions = reg.counter(
            "serve_preemptions_total", "requests preempted under pool pressure")

    def submit(self, req: Request) -> None:
        req.state = WAITING
        self.waiting.append(req)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def admit(self) -> List[Request]:
        """Move queue heads into the running set while a slot and enough
        blocks for their worst case are available (FIFO, no overtaking).
        Capacity admitted earlier in the same call is held back, so one
        admit() batch never promises the same blocks twice."""
        admitted = []
        reserved = 0
        if not self.waiting:
            # nothing to admit: skip the span too — at steady state this
            # is every step, and an empty admit span per decode step is
            # pure tracing overhead (the obs_overhead_pct bar is tight)
            return admitted
        with trace.span("serve.admit", waiting=len(self.waiting),
                        running=len(self.running)):
            # prefix-cached blocks in the LRU are evictable on demand, so
            # they count as admissible capacity (a hit needs even less)
            avail = getattr(self.pool, "available_blocks",
                            self.pool.free_blocks)
            while self.waiting and len(self.running) < self.max_running:
                req = self.waiting[0]
                need = self.pool.blocks_for(req.cache_budget()
                                            + self.headroom_tokens)
                if (need + reserved > avail
                        or len(admitted) + 1 > self.pool.free_slots):
                    break
                reserved += need
                self.waiting.popleft()
                req.state = RUNNING
                req.admit_seq = self._admit_seq
                self._admit_seq += 1
                self.running.append(req)
                admitted.append(req)
                self._c_admitted.inc()
                wait = time.perf_counter() - req.arrival_time
                self._h_queue_wait.observe(wait)
                if self.flight is not None:
                    self.flight.record("admit", req_id=req.req_id,
                                       queue_wait_s=wait, blocks=need,
                                       preemptions=req.preemptions)
        return admitted

    def adopt(self, req: Request) -> None:
        """Insert an already-provisioned request (a fork) into the running
        set directly, bypassing the admission queue."""
        assert len(self.running) < self.max_running, "running set full"
        req.state = RUNNING
        req.admit_seq = self._admit_seq
        self._admit_seq += 1
        self.running.append(req)

    def evict(self, req: Request) -> None:
        """Finished request: free its blocks and leave the running set."""
        self.pool.free(req.req_id)
        self.running.remove(req)
        req.state = FINISHED
        req.finish_time = time.perf_counter()
        if self.flight is not None:
            self.flight.record("evict", req_id=req.req_id,
                               out_tokens=len(req.out_tokens))

    def preempt_youngest(self) -> Optional[Request]:
        """Free the most recently admitted request and requeue it at the
        front; returns it, or None if nothing is running."""
        if not self.running:
            return None
        victim = max(self.running, key=lambda r: r.admit_seq)
        with trace.span("serve.preempt", req_id=victim.req_id,
                        generated=len(victim.out_tokens)):
            self.pool.free(victim.req_id)
            self.running.remove(victim)
            victim.state = WAITING
            victim.cache_len = 0
            victim.preemptions += 1
            self._c_preemptions.inc()
            self.waiting.appendleft(victim)
            if self.flight is not None:
                self.flight.record("preempt", req_id=victim.req_id,
                                   generated=len(victim.out_tokens),
                                   preemptions=victim.preemptions)
        return victim
