"""Async host pipeline: detokenize + stream callbacks off the dispatch thread.

``ContinuousEngine.step()`` must return as soon as the next device step is
dispatched — per-token host work (detokenizing the emitted token, invoking
the user's stream callback) has no business on that thread. This module
gives the engine a single background worker thread fed by a FIFO queue:
every token the engine emits is enqueued as an O(1) handoff, and the worker
detokenizes and runs callbacks in emission order (one queue, one consumer,
so per-request event order is exactly the emission order — token-identical
to the synchronous inline path, which ``async_detok=False`` keeps as the
in-tree oracle).

The worker names its own lane in the span tracer (``trace.name_thread``),
so a ``--trace-out`` capture shows detokenize/callback spans on a separate
track from the device-dispatch thread — the MaxText MLPerf harness's
background detokenize thread, in this engine's vocabulary.

The thread starts lazily on the first emission (engines without callbacks
or a detokenizer never spawn it) and is a daemon; ``flush()`` blocks until
every enqueued event has been delivered (``ContinuousEngine.run()`` and
``run_offline()`` flush before returning). Callback exceptions are counted
(``callback_errors``) and swallowed — a user callback must not be able to
kill the serving pipeline.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Optional

from repro.obs import trace


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One streamed token, as delivered to a request's ``stream_callback``."""
    req_id: int
    index: int                  # 0-based position in the request's output
    token: int
    text: Optional[str]         # detokenized piece (None without detokenizer)
    done: bool                  # True on the request's final token


def deliver(req, token: int, index: int, done: bool,
            detokenizer: Optional[Callable[[int], str]]) -> None:
    """Detokenize one token into ``req.text`` and fire its callback — the
    shared delivery step of the async worker and the synchronous oracle."""
    piece = None
    if detokenizer is not None:
        piece = detokenizer(token)
        req.text += piece
    if req.stream_callback is not None:
        req.stream_callback(StreamEvent(req_id=req.req_id, index=index,
                                        token=token, text=piece, done=done))


class DetokenizeWorker:
    """FIFO background consumer for detokenize + stream-callback work."""

    def __init__(self, detokenizer: Optional[Callable[[int], str]] = None,
                 name: str = "serve-detokenize"):
        self.detokenizer = detokenizer
        self.callback_errors = 0
        self._name = name
        self._q: queue.Queue = queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    def _ensure_thread(self) -> None:
        with self._lock:
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(target=self._run, daemon=True,
                                                name=self._name)
                self._thread.start()

    def submit(self, req, token: int, index: int, done: bool) -> None:
        """Enqueue one emission; O(1) on the caller (dispatch) thread."""
        self._ensure_thread()
        self._q.put((req, token, index, done))

    def _run(self) -> None:
        trace.name_thread("detokenize")
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                req, token, index, done = item
                with trace.span("serve.detokenize", req_id=req.req_id,
                                index=index, done=done):
                    try:
                        deliver(req, token, index, done, self.detokenizer)
                    except Exception:
                        self.callback_errors += 1
            finally:
                self._q.task_done()

    def flush(self) -> None:
        """Block until every enqueued event has been delivered."""
        self._q.join()

    def close(self) -> None:
        """Drain, then stop the worker thread (it restarts on next use)."""
        self.flush()
        with self._lock:
            t = self._thread
            if t is None or not t.is_alive():
                return
            self._q.put(None)
            self._thread = None
        t.join()
