from repro.serve.engine import ContinuousEngine, ServeEngine  # noqa: F401
from repro.serve.paged_cache import BlockPool, CacheLayout  # noqa: F401
from repro.serve.recalibrate import (  # noqa: F401
    RecalibPolicy, RecalibWorker, TrafficCalibrator)
from repro.serve.scheduler import Request, Scheduler  # noqa: F401
