from repro.serve.engine import ServeEngine  # noqa: F401
