"""Live-traffic recalibration: stream serving activations back into COALA
and hot-swap refreshed factors into a running engine without draining.

The paper's scenario (3) — insufficient calibration data — comes with
explicit error bounds, which means a *running server* can know when
traffic-derived calibration has seen enough tokens to produce a
trustworthy approximation. This module closes that loop:

  * ``TrafficCalibrator`` duck-types (subclasses) ``core.calibrate.
    Calibrator``: a sampled fraction of requests have their served token
    streams replayed through the model's unrolled-eager capture path
    (``LM.capture_prefill``) into the same per-layer streaming-R
    accumulators offline calibration uses — so ``compress_model`` /
    ``compress_model_pair`` and the ``obs.numerics`` monitors work
    unchanged. Each served position is captured exactly once: the prompt
    at admission, the generated inputs at completion (causality makes the
    position-sliced replay the exact activations serving computed), so
    the traffic R equals an offline ``Calibrator`` fed the same streams
    as RᵀR up to TSQR chunk-order roundoff (tests/test_compress.py pins
    that invariance; benchmarks gate the parity).

  * ``RecalibWorker`` watches the three numerics grades — data volume,
    conditioning, residual-vs-bound — and recompresses once the *bound
    clears* the policy:

      1. **data**: every target layer has streamed ``min_token_factor × n``
         tokens. The default (0.25) sits deliberately below the offline
         monitors' factor of 1.0: the μ-regularized solve is exactly the
         paper's cure for the under-streamed regime, so the worker does
         not wait for full-rank data — the remaining gates decide.
      2. **conditioning**: no layer's μ-augmented R̃ (the factor the
         Prop. 3 solve actually uses; ``obs.numerics.
         check_augmented_r_factors``) grades FAIL.
      3. **bound**: every recompressed layer's achieved residual
         ``‖(W−W')R̃ᵀ‖/‖WR̃ᵀ‖`` is within ``max_residual_excess`` of the
         attainable Σ-tail bound (``obs.numerics.check_compression``) —
         a solver that silently lost accuracy never ships.

    Ranks are pinned from the serving factors' original compression
    (``core.compress.rank_map_from_reports``), so the refreshed pytree
    has identical treedef/shapes/dtypes and ``ContinuousEngine.
    hot_swap`` is a pure value swap: params are traced jit *arguments*
    (never donated), the existing cache entries hit, and
    ``post_warmup_compiles`` stays 0 across a swap. In-flight requests
    keep their KV pages and continue token-exactly on the new factors'
    forward pass — swapping identical values is asserted to be a perfect
    no-op (tests/test_recalibrate.py, tests/test_soak_serve.py).

The worker runs inline by default — ``on_step`` polls the gates between
engine steps, deterministic and test-friendly. ``async_solve=True`` moves
the solve to a background thread that *stages* the params; the engine
applies the staged swap at the top of its next ``step()``, so the swap
still lands between steps, never mid-dispatch.
"""
from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.calibrate import Calibrator
from repro.core.compress import compress_model
from repro.obs import numerics, trace

FAIL = numerics.FAIL


@dataclass(frozen=True)
class RecalibPolicy:
    """When is traffic-derived calibration trustworthy enough to ship?

    ``min_token_factor`` is the *data* gate (tokens per layer >= factor ×
    features); 0.25 by default — deliberately below the offline monitors'
    1.0 because the μ-regularized solve is well-posed under partial data
    (Prop. 3 is the paper's cure for exactly this regime) and the
    conditioning + residual-vs-bound gates do the real vetting. A swap
    is attempted at most every ``check_every`` engine steps, and after a
    swap (or a failed bound check) only once ``min_new_tokens`` fresh
    tokens have streamed in."""
    sample_rate: float = 1.0        # fraction of requests captured
    min_token_factor: float = 0.25  # data gate: tokens >= factor * n
    max_residual_excess: float = 2.0  # bound gate: residual <= excess * bound
    fail_cond: float = 1e8          # conditioning gate on μ-augmented R̃
    check_every: int = 2            # poll cadence, in engine steps
    min_new_tokens: int = 32        # fresh tokens between solve attempts
    capture_generated: bool = True  # replay generated inputs at completion


class TrafficCalibrator(Calibrator):
    """``Calibrator`` fed by live traffic instead of a calibration set.

    Capture is incremental and exactly-once per served position: a sampled
    request's prompt is replayed at admission and its generated *inputs*
    (every emitted token except the last, which no forward pass consumed)
    at completion, each time recording only positions not yet captured.
    The position slicing lives in the ``record`` override so the model's
    capture path stays byte-identical to offline calibration."""

    def __init__(self, model, *, ctx=None, policy: RecalibPolicy = None,
                 dtype=None, compute_dtype=None, seed: int = 0):
        import jax.numpy as jnp
        from repro.models.common import CPU_CTX
        super().__init__(dtype=dtype or jnp.float32)
        self.model = model
        self.ctx = CPU_CTX if ctx is None else ctx
        self.policy = policy or RecalibPolicy()
        self.compute_dtype = compute_dtype or jnp.float32
        self._rng = np.random.RandomState(seed)
        self._rec_start = 0
        # req_id -> number of stream positions captured so far; sampling is
        # sticky (a request is in or out for its whole lifetime)
        self._sampled: Dict[int, int] = {}
        self._rejected: set = set()
        self.sampled_requests = 0
        self.captured_tokens = 0
        # full streams captured from finished requests, for offline-parity
        # replay (benchmarks/run.py feeds these to a plain Calibrator)
        self.captured_streams: List[np.ndarray] = []

    # ------------------------------------------------------------ capture
    def record(self, path: str, x) -> None:
        if self._rec_start and getattr(x, "ndim", 2) >= 3:
            x = x[:, self._rec_start:]
        super().record(path, x)

    def capture(self, base_params, tokens, *, start: int = 0) -> None:
        """Replay ``tokens`` (T,) through the eager capture path, recording
        only positions >= ``start`` (each conditioned on its full prefix)."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if len(tokens) <= start:
            return
        with trace.span("serve.recalib_capture", tokens=len(tokens) - start,
                        start=start):
            self._rec_start = start
            try:
                self.model.capture_prefill(base_params, tokens, self,
                                           ctx=self.ctx,
                                           compute_dtype=self.compute_dtype)
            finally:
                self._rec_start = 0
        self.captured_tokens += len(tokens) - start

    def _admit(self, req_id: int) -> bool:
        if req_id in self._sampled:
            return True
        if req_id in self._rejected:
            return False
        if self._rng.random_sample() < self.policy.sample_rate:
            self._sampled[req_id] = 0
            self.sampled_requests += 1
            return True
        self._rejected.add(req_id)
        return False

    def on_prefill(self, base_params, req) -> None:
        """Admission-time capture of the tokens this prefill computes over
        (prompt, or prompt + generated-so-far for a resumed preemptee)."""
        if not self._admit(req.req_id):
            return
        stream = np.asarray(req.prefill_tokens(), np.int32)
        done = self._sampled[req.req_id]
        self.capture(base_params, stream, start=done)
        self._sampled[req.req_id] = max(done, len(stream))

    def on_finish(self, base_params, req) -> None:
        """Completion-time capture of the generated inputs (everything the
        decode loop fed back in: ``out_tokens[:-1]``)."""
        done = self._sampled.pop(req.req_id, None)
        self._rejected.discard(req.req_id)
        if done is None:
            return
        stream = np.concatenate(
            [np.asarray(req.prompt, np.int32),
             np.asarray(req.out_tokens[:-1], np.int32)])
        if self.policy.capture_generated and len(stream) > done:
            self.capture(base_params, stream, start=done)
            done = len(stream)
        self.captured_streams.append(stream[:done])


class RecalibWorker:
    """Watches the numerics gates over a ``TrafficCalibrator`` and hot-swaps
    recompressed factors into a live ``ContinuousEngine``.

    Attach with ``engine.attach_recalibrator(worker)``; the engine then
    calls ``on_prefill`` / ``on_finish`` on the capture path and
    ``on_step`` at the top of every ``step()`` (which applies staged swaps
    and, in inline mode, polls the gates)."""

    def __init__(self, model, base_params, cal: TrafficCalibrator, ccfg, *,
                 rank_map: Dict[str, int],
                 draft_ratio: float = 0.0,
                 draft_rank_map: Optional[Dict[str, int]] = None,
                 async_solve: bool = False):
        if not rank_map:
            raise ValueError("rank_map is empty: nothing to recompress "
                             "(pin it from the initial compression's "
                             "reports via rank_map_from_reports)")
        self.model = model
        self.base_params = base_params
        self.cal = cal
        self.ccfg = ccfg
        self.rank_map = dict(rank_map)
        self.draft_ratio = float(draft_ratio)
        self.draft_rank_map = dict(draft_rank_map) if draft_rank_map else None
        if self.draft_ratio > 0 and not self.draft_rank_map:
            raise ValueError("draft recompression needs draft_rank_map")
        self.policy = cal.policy
        self.async_solve = async_solve
        # observable state
        self.swaps = 0
        self.solve_attempts = 0
        self.last_status = "collecting"
        self.last_excess = float("nan")
        self.last_swap_seconds = float("nan")
        self.tokens_at_first_swap: Optional[int] = None
        self._steps = 0
        self._tokens_at_last_solve = -(10 ** 9)
        self._staged = None
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._metrics = {}
        # set by engine.attach_recalibrator: lets the async solve path (no
        # engine argument) reach the flight recorder / postmortem dump when
        # a readiness gate rejects a solve
        self._engine = None

    # ------------------------------------------------------------ metrics
    def bind_metrics(self, **counters) -> None:
        """Engine-owned ``serve_recalib_*`` counters the worker increments
        (``attach_recalibrator`` wires them up)."""
        self._metrics = counters

    def _inc(self, name: str, by=1) -> None:
        c = self._metrics.get(name)
        if c is not None:
            c.inc(by)

    # ------------------------------------------------------------ hooks
    def on_prefill(self, engine, req) -> None:
        before_r, before_t = self.cal.sampled_requests, self.cal.captured_tokens
        self.cal.on_prefill(self.base_params, req)
        self._inc("sampled", self.cal.sampled_requests - before_r)
        self._inc("tokens", self.cal.captured_tokens - before_t)
        self._record_capture(engine, req, self.cal.captured_tokens - before_t,
                             at="prefill")

    def on_finish(self, engine, req) -> None:
        before_t = self.cal.captured_tokens
        self.cal.on_finish(self.base_params, req)
        self._inc("tokens", self.cal.captured_tokens - before_t)
        self._record_capture(engine, req, self.cal.captured_tokens - before_t,
                             at="finish")

    @staticmethod
    def _record_capture(engine, req, tokens: int, *, at: str) -> None:
        fl = getattr(engine, "flight", None)
        if fl is not None and tokens > 0:
            fl.record("recalib_capture", req_id=req.req_id,
                      tokens=int(tokens), at=at)

    def on_step(self, engine) -> None:
        """Between-steps hook: apply any staged swap, then (inline mode)
        poll the gates every ``check_every`` steps; in async mode kick the
        solver thread instead so ``step()`` never blocks on a solve."""
        self._steps += 1
        with self._lock:
            staged, self._staged = self._staged, None
        if staged is not None:
            self._apply(engine, *staged)
        if self._steps % max(self.policy.check_every, 1) != 0:
            return
        if self.async_solve:
            if (self._thread is None or not self._thread.is_alive()) \
                    and self._should_solve():
                self._thread = threading.Thread(
                    target=self._solve_and_stage, daemon=True)
                self._thread.start()
        else:
            self.poll(engine)

    # ------------------------------------------------------------ gates
    def min_tokens_seen(self) -> int:
        seen = self.cal.tokens_seen()
        return min((seen.get(p, 0) for p in self.rank_map), default=0)

    def clearance(self) -> float:
        """min over target layers of tokens_seen / (min_token_factor × n):
        the data gate clears at >= 1.0. Layers with no stream yet pin 0."""
        seen = self.cal.tokens_seen()
        dims = {p: int(r.shape[0]) for p, r in self.cal.r_factors().items()}
        worst = math.inf
        for p in self.rank_map:
            if p not in dims:
                return 0.0
            need = self.policy.min_token_factor * dims[p]
            worst = min(worst, seen.get(p, 0) / max(need, 1e-9))
        return 0.0 if worst is math.inf else float(worst)

    def _should_solve(self) -> bool:
        if self.clearance() < 1.0:
            self.last_status = "collecting"
            return False
        if (self.cal.captured_tokens - self._tokens_at_last_solve
                < self.policy.min_new_tokens):
            return False
        return True

    # ------------------------------------------------------------ solve/swap
    def poll(self, engine) -> bool:
        """Inline gate check + solve + swap; returns True if a swap landed."""
        if not self._should_solve():
            return False
        result = self._solve()
        if result is None:
            return False
        self._apply(engine, *result)
        return True

    def _solve_and_stage(self) -> None:
        result = self._solve()
        if result is not None:
            with self._lock:
                self._staged = result

    def _solve(self):
        """Recompress against the traffic R factors and vet the result;
        returns (params, draft_params) or None when a gate fails."""
        import dataclasses as dc
        self.solve_attempts += 1
        self._tokens_at_last_solve = self.cal.captured_tokens
        with trace.span("serve.recalib_solve",
                        tokens=self.cal.captured_tokens):
            new_params, reports = compress_model(
                self.model, self.base_params, self.cal, self.ccfg,
                rank_map=self.rank_map)
            draft_params = None
            if self.draft_ratio > 0:
                dcfg = dc.replace(self.ccfg, ratio=self.draft_ratio, rank=0)
                draft_params, _ = compress_model(
                    self.model, self.base_params, self.cal, dcfg,
                    rank_map=self.draft_rank_map)
        with trace.span("serve.recalib_check"):
            pol = numerics.NumericsPolicy(
                fail_cond=self.policy.fail_cond,
                min_token_factor=self.policy.min_token_factor,
                warn_residual_excess=self.policy.max_residual_excess,
                fail_residual_excess=self.policy.max_residual_excess)
            mus = {r.path: r.mu for r in reports}
            target_rf = {p: r for p, r in self.cal.r_factors().items()
                         if p in self.rank_map}
            conds = numerics.check_augmented_r_factors(
                target_rf, mus, self.cal.tokens_seen(), pol)
            comp = numerics.check_compression(reports, pol)
            excesses = [h.residual / max(h.bound, 1e-12) for h in comp]
            self.last_excess = max(excesses) if excesses else float("nan")
            cond_fail = [h for h in conds
                         if not math.isfinite(h.cond)
                         or h.cond >= self.policy.fail_cond]
            bound_fail = [h for h in comp if h.level == FAIL]
        if cond_fail or bound_fail:
            self.last_status = ("cond_fail" if cond_fail else "bound_fail")
            trace.instant("serve.recalib_reject", status=self.last_status,
                          layers=len(cond_fail) + len(bound_fail))
            # a gate rejection means the numerics monitors graded the solve
            # untrustworthy — exactly the moment the postmortem bundle is
            # worth having (engine/flight wiring is optional; no-op without)
            eng = self._engine
            fl = getattr(eng, "flight", None)
            if fl is not None:
                fl.record("recalib_reject", status=self.last_status,
                          layers=len(cond_fail) + len(bound_fail),
                          excess=float(self.last_excess)
                          if math.isfinite(self.last_excess) else None)
                eng.dump_postmortem(f"recalib_{self.last_status}")
            return None
        self.last_status = "cleared"
        return new_params, draft_params

    def _apply(self, engine, new_params, draft_params) -> None:
        t0 = time.perf_counter()
        engine.hot_swap(new_params, draft_params)
        self.last_swap_seconds = time.perf_counter() - t0
        self.swaps += 1
        self._inc("swaps")
        if self.tokens_at_first_swap is None:
            self.tokens_at_first_swap = self.cal.captured_tokens
        self.last_status = "swapped"

    def summary(self) -> Dict[str, float]:
        return {
            "swaps": self.swaps,
            "solve_attempts": self.solve_attempts,
            "sampled_requests": self.cal.sampled_requests,
            "captured_tokens": self.cal.captured_tokens,
            "clearance": self.clearance(),
            "residual_excess": self.last_excess,
            "status": self.last_status,
        }
