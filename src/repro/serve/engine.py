"""Serving engines.

``ServeEngine`` — the original fixed-batch loop: one synchronized batch, a
dense monolithic KV cache, everything decodes in lockstep. Kept as the
fallback/oracle path.

``ContinuousEngine`` — request-level continuous batching over a paged KV
cache. ``submit()`` enqueues a request; each ``step()`` admits whatever fits
(scheduler + block pool), prefills joiners into pool blocks, then runs ONE
decode step over the whole running set at per-request positions (the
models' vector-``pos`` decode path), so requests of different lengths
interleave freely and finished requests free their blocks immediately.
Per-request sampling params (greedy + temperature) are applied row-wise;
sampling keys are folded per (seed, output index) so a preempted request
resumes on the same trajectory.

Prefill path (pure-attention LMs): admission looks up the longest cached
block-aligned prefix in the pool's prefix registry (``prefix_cache``,
auto-on; token-exact intern chains over full blocks) and only the *suffix*
is computed; joiners whose suffixes land in the same length bucket
(``prefill_bucket_sizes``, default powers of two with floor 8) prefill
together in ONE jitted ``LM.prefill_chunk`` call at per-row cache offsets
— so prefill compiles per (batch, length, blocks) bucket instead of per
prompt length (``metrics()["prefill_compiles"]``). By default
(``prefill_kernel=True`` where the model supports it) that call runs the
chunked-prefill kernel (``kernels/chunked_prefill.py``) directly against
the pool's page stores with the per-request block tables: attention
scatters the suffix K/V into its pages and attends through the table
indirection with per-row prefix-offset causal masks — no gather or
scatter of the cache; the donated stores flow back via ``absorb_paged``.
``prefill_kernel=False`` keeps the gather-into-contiguous path as the
in-tree oracle. ``fork()`` clones a running request copy-on-write for
best-of-n sampling. Models with extras (whisper frames, VLM vision
prefixes) and recurrent/hybrid archs keep the legacy per-request prefill.

Decode read path: by default (``paged_kernel=True`` where the model
supports it) each step passes the pool's page stores *directly* into the
jitted ``decode_step`` together with the per-request block tables — the
attention layers resolve the indirection in-kernel
(``kernels/paged_attention.py``) and write the new token into its page, so
no contiguous copy of the KV history is ever materialized and the updated
page stores flow straight back into the pool (``absorb_paged`` swaps array
references; the cache argument is donated so XLA updates pages in place).
The legacy gather path (``paged_kernel=False``) assembles the contiguous
pytree ``init_cache`` would have produced and remains the oracle — under
greedy decoding both are token-identical to ``ServeEngine``
(tests/test_serve_continuous.py asserts this).

Shape buckets: the decode batch is padded to the next size in
``bucket_sizes`` and the block envelope to the next power of two, so
``step()`` hits a small closed set of jit signatures instead of recompiling
every time traffic shifts; ``metrics()["decode_compiles"]`` exposes the
compile-cache counter that tests/test_serve_buckets.py guards. Padding rows
read/write the pool's trash page and trash state slot.

Warm start: because decode pads to shape buckets and prefill to
(batch, length, blocks) buckets, the set of jit signatures any admissible
trace can hit is *closed and enumerable* — ``warmup(max_len=...)``
enumerates exactly that set (``warmup_signatures``) and executes every
signature once against the pool's trash page before traffic arrives, so
the first request's TTFT equals steady-state TTFT and
``metrics()["post_warmup_compiles"]`` stays 0 under any traffic whose
per-request cache need fits ``max_len`` (tests/test_warmup.py asserts
``== 0``, not ``≤ buckets``). The pool pre-compiles its own maintenance
jits (block zeroing, COW copy) in the same pass.

Async host pipeline: per-token host work — detokenizing and the user's
``stream_callback`` — runs on a background worker thread fed by a FIFO
queue (``serve/detokenize.py``), so ``step()`` returns as soon as the next
device step is dispatched. ``async_detok=False`` keeps the inline
synchronous path as the ordering/parity oracle; ``run()`` flushes the
worker before returning.

Offline lane: ``run_offline(requests)`` is the MLPerf-style
throughput-bound mode — sort by prompt length so same-bucket prompts are
admitted together and pack into shared bucketed prefill calls, drive to
drain, return results in input order.

Speculative decoding (``draft_params=...``): the engine serves the target
model and a COALA-compressed draft of it side by side, each against its
own paged pool (identical geometry — compression only changes weights).
Every decode round, one jitted ``lax.scan`` over ``spec_k + 1`` draft
steps proposes ``spec_k`` tokens per request (sampling in-scan, so the
whole proposal costs a single dispatch), then the target scores all
``spec_k + 1`` positions in one ``verify_chunk`` call riding the PR-4
L-token paged write path. Greedy rows accept the longest prefix of
proposals matching the target argmax (token-exact vs the non-speculative
engine by induction); temperature rows run standard rejection sampling
(accept ``d_i`` w.p. ``min(1, p/q)``, residual draw from
``norm(max(p-q, 0))``, bonus draw after a full accept). Rejected tail
pages are rolled back via ``BlockPool.truncate``; acceptance is exported
as ``serve_spec_*`` counters and ``metrics()["spec_accept_rate"]``.

docs/serving.md documents the page/block/intern-chain/bucket vocabulary,
the request data flow, the warmup lifecycle, and every CLI knob;
docs/kernels.md documents the decode and chunked-prefill kernels this
engine drives.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import CPU_CTX, ParallelCtx
from repro.models.transformer import LM, period_specs
from repro.obs import trace
from repro.obs.metrics import LATENCY_BUCKETS, Registry
from repro.serve.detokenize import DetokenizeWorker, deliver
from repro.serve.paged_cache import BlockPool
from repro.serve.scheduler import Request, Scheduler


def _pow2_at_least(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def default_bucket_sizes(max_running: int) -> tuple:
    """Power-of-two batch buckets covering [1, max_running]."""
    sizes = []
    b = 1
    while b < max_running:
        sizes.append(b)
        b *= 2
    return tuple(sizes) + (max_running,)


@dataclasses.dataclass
class ServeEngine:
    model: object
    params: object
    ctx: ParallelCtx = CPU_CTX
    compute_dtype: object = jnp.bfloat16
    cache_dtype: object = jnp.bfloat16

    def __post_init__(self):
        m, ctx, cd = self.model, self.ctx, self.compute_dtype
        self._prefill = jax.jit(
            lambda p, tk, c, **kw: m.prefill(p, tk, c, ctx=ctx,
                                             compute_dtype=cd, **kw))
        self._decode = jax.jit(
            lambda p, tk, c, pos: m.decode_step(p, tk, c, pos, ctx=ctx,
                                                compute_dtype=cd))

    def generate(self, prompt_tokens, max_new_tokens: int, *,
                 extras: Optional[Dict] = None, temperature: float = 0.0,
                 seed: int = 0, max_len: Optional[int] = None):
        """prompt_tokens: (B, T_prompt) int32 -> (B, T_prompt+new) int32."""
        b, t0 = prompt_tokens.shape
        kw = dict(extras or {})
        # vlm: the vision prefix occupies the first cache positions, so the
        # cache and the decode write positions are offset by its length
        vis = 0
        cfg = getattr(self.model, "cfg", None)
        if ("vision_embeds" in kw and cfg is not None
                and getattr(cfg, "family", "") == "vlm"):
            vis = kw["vision_embeds"].shape[1]
        total = max_len or (vis + t0 + max_new_tokens)
        cache = self.model.init_cache(b, total, dtype=self.cache_dtype)
        logits, cache = self._prefill(self.params, prompt_tokens, cache, **kw)
        logits = logits[:, -1] if logits.ndim == 3 else logits
        out = [prompt_tokens]
        key = jax.random.PRNGKey(seed)
        tok = self._sample(logits, temperature, key)
        for i in range(max_new_tokens):
            out.append(tok)
            if i == max_new_tokens - 1:
                break
            pos = jnp.asarray(vis + t0 + i, jnp.int32)
            logits, cache = self._decode(self.params, tok, cache, pos)
            key, sk = jax.random.split(key)
            tok = self._sample(logits, temperature, sk)
        return jnp.concatenate(out, axis=1)

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature)[:, None] \
            .astype(jnp.int32)


def _sample_rows(logits, temps, keys):
    """Row-wise sampling: greedy where temp <= 0, categorical otherwise."""
    def one(lg, temp, key):
        greedy = jnp.argmax(lg, axis=-1)
        samp = jax.random.categorical(key, lg / jnp.maximum(temp, 1e-6))
        return jnp.where(temp > 0.0, samp, greedy).astype(jnp.int32)
    return jax.vmap(one)(logits, temps, keys)


# key-derivation fold tags decorrelating the speculative streams from the
# engine's per-(seed, output-index) decode keys and from each other
_DRAFT_FOLD = 0x0D1A           # in-scan draft proposal sampling
_ACCEPT_FOLD = 0xACC           # host-side accept/residual draws
_BONUS_FOLD = 0xB0E5           # host-side bonus draw after a full accept


def _softmax_np(x: np.ndarray) -> np.ndarray:
    x = x - np.max(x)
    e = np.exp(x)
    return e / e.sum()


class ContinuousEngine:
    """Request-level serving: ``submit()`` / ``step()`` / ``stream()``."""

    def __init__(self, model, params, *, ctx: ParallelCtx = CPU_CTX,
                 compute_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16,
                 block_size: int = 16, num_blocks: int = 512,
                 max_running: int = 8,
                 paged_kernel: Optional[bool] = None,
                 prefill_kernel: Optional[bool] = None,
                 paged_attn_impl: Optional[str] = None,
                 bucket_sizes: Optional[Sequence[int]] = None,
                 prefix_cache: Optional[bool] = None,
                 prefill_bucket_sizes: Optional[Sequence[int]] = None,
                 detokenizer: Optional[Callable[[int], str]] = None,
                 async_detok: Optional[bool] = None,
                 draft_params=None, spec_k: int = 4,
                 slo_ttft_s: Optional[float] = None,
                 slo_tpot_s: Optional[float] = None,
                 flight_recorder=None):
        self.model = model
        self.params = params
        # live-telemetry plane (docs/observability.md): an optional flight
        # recorder of per-request lifecycle events, per-request latency SLOs
        # feeding the goodput gauge (None = every request trivially meets
        # them), and the step/liveness bookkeeping /healthz reads
        self.flight = flight_recorder
        self.slo_ttft_s = slo_ttft_s
        self.slo_tpot_s = slo_tpot_s
        self._step_idx = 0
        self._swap_epoch = 0
        self.last_step_time: Optional[float] = None
        self.warmed = False
        if paged_attn_impl is not None:
            ctx = dataclasses.replace(ctx, paged_attn_impl=paged_attn_impl)
        self.ctx = ctx
        self.compute_dtype = compute_dtype
        self.cache_dtype = cache_dtype
        self.block_size = block_size
        # chunked (position-offset) prefill rides the vector-pos attention
        # path, so it needs a pure-attention LM: recurrent/hybrid layers
        # (mamba, xlstm) would need state snapshots at block boundaries
        chunk_ok = isinstance(model, LM)
        if chunk_ok:
            pre, per, _ = period_specs(model.cfg)
            chunk_ok = all(s.kind == "attn" for s in pre + per)
        self._chunk_ok = chunk_ok
        self.prefix_cache = chunk_ok if prefix_cache is None else prefix_cache
        if self.prefix_cache and not chunk_ok:
            raise ValueError(
                "prefix caching needs chunked suffix prefill, which this "
                "model does not support (recurrent/hybrid/enc-dec layers)")
        # speculative decoding: a (COALA-compressed) draft shares the target
        # model's architecture, so its paged pool has identical geometry and
        # the verifier is the chunked-prefill path scored at every position
        self.draft_params = draft_params
        self.spec_k = int(spec_k)
        self._spec = draft_params is not None
        if self._spec and not chunk_ok:
            raise ValueError(
                "speculative decoding needs the chunked (position-offset) "
                "prefill path as its verifier (pure-attention LM)")
        if self._spec and self.spec_k < 1:
            raise ValueError("spec_k must be >= 1")
        # one registry per engine: pool and scheduler register their own
        # series into it, metrics() is a compatibility view over it, and
        # launch/serve.py --metrics-out writes its Prometheus exposition
        self.registry = Registry()
        self.pool = BlockPool(model, num_blocks=num_blocks,
                              block_size=block_size,
                              max_requests=max_running, dtype=cache_dtype,
                              prefix_cache=self.prefix_cache,
                              registry=self.registry)
        self.scheduler = Scheduler(self.pool, max_running=max_running,
                                   registry=self.registry,
                                   headroom_tokens=self.spec_k
                                   if self._spec else 0,
                                   flight=flight_recorder)
        # the draft decodes against its own pool (private registry: the
        # engine registry's pool_* series describe the target pool), kept in
        # lockstep with the target's — same allocs, commits, forks, frees —
        # so cached-prefix hits and table shapes mirror exactly
        self.draft_pool = BlockPool(
            model, num_blocks=num_blocks, block_size=block_size,
            max_requests=max_running, dtype=cache_dtype,
            prefix_cache=self.prefix_cache) if self._spec else None
        # the paged read path needs attention layers that understand page
        # stores: decoder-only/VLM/hybrid LMs with plain GQA K/V caches
        # (MLA keeps latent caches; enc-dec models route through EncDecLM)
        supported = isinstance(model, LM) and not model.cfg.kv_lora_rank
        self.paged_kernel = supported if paged_kernel is None else paged_kernel
        if self.paged_kernel and not supported:
            raise ValueError(
                "paged decode kernel unsupported for this model (MLA/enc-dec)")
        # the chunked-prefill kernel needs both the chunked suffix-prefill
        # path (pure-attention LM) and page-store-aware attention (plain GQA
        # K/V caches, no MLA latents)
        prefill_supported = chunk_ok and supported
        self.prefill_kernel = (prefill_supported if prefill_kernel is None
                               else prefill_kernel)
        if self.prefill_kernel and not prefill_supported:
            raise ValueError(
                "chunked-prefill kernel unsupported for this model "
                "(recurrent/hybrid/MLA/enc-dec layers)")
        buckets = set(bucket_sizes or default_bucket_sizes(max_running))
        buckets.add(max_running)        # largest bucket must cover the batch
        self.bucket_sizes = tuple(sorted(buckets))
        self.prefill_bucket_sizes = tuple(sorted(prefill_bucket_sizes)) \
            if prefill_bucket_sizes else ()
        self.finished: List[Request] = []
        self._next_id = 0
        self._start_time: Optional[float] = None
        self._recalib = None            # attach_recalibrator() installs one
        self._decode_shapes: set = set()
        self._prefill_shapes: set = set()
        self._spec_shapes: set = set()          # draft-scan + verify rounds
        self._draft_prefill_shapes: set = set()  # prefill run with draft params
        # async host pipeline: detokenize + stream callbacks run on the
        # worker's thread (lazily started on first emission); off = inline
        # synchronous delivery, the ordering/parity oracle
        self.detokenizer = detokenizer
        self.async_detok = True if async_detok is None else async_detok
        self._detok = DetokenizeWorker(detokenizer) if self.async_detok \
            else None
        # warm-start bookkeeping: compile-cache sizes recorded when
        # warmup() finishes, so post_warmup_compiles() counts only jit
        # signatures traffic hit that warmup failed to cover
        self._warmup_seconds = 0.0
        self._warmed_decode = 0
        self._warmed_prefill = 0
        # typed registry series replacing the former hand-rolled counter
        # attributes; the steady-state throughput pairs (tokens + seconds)
        # exclude steps that compiled a fresh jit signature
        reg = self.registry
        self._c_decode_steps = reg.counter(
            "serve_decode_steps_total", "decode steps run")
        self._c_decode_tokens = reg.counter(
            "serve_decode_tokens_total",
            "steady-state decoded tokens (compile steps excluded)")
        self._c_decode_seconds = reg.counter(
            "serve_decode_seconds_total",
            "steady-state decode wall time (compile steps excluded)")
        self._c_prefill_batches = reg.counter(
            "serve_prefill_batches_total", "batched suffix prefill calls")
        self._c_prefill_tokens = reg.counter(
            "serve_prefill_tokens_total",
            "steady-state prefilled suffix tokens (compiles excluded)")
        self._c_prefill_seconds = reg.counter(
            "serve_prefill_seconds_total",
            "steady-state batched-prefill wall time (compiles excluded)")
        self._c_prompt_tokens = reg.counter(
            "serve_prompt_tokens_total", "prompt tokens submitted to prefill")
        self._c_prefix_hit_tokens = reg.counter(
            "serve_prefix_hit_tokens_total",
            "prompt tokens satisfied from the prefix cache")
        self._c_finished = reg.counter(
            "serve_requests_finished_total", "requests run to completion")
        self._c_new_tokens = reg.counter(
            "serve_new_tokens_total", "tokens generated by finished requests")
        if self._spec:
            # registered only in speculative mode: the non-spec registry
            # schema (docs/observability.md, tests/test_obs.py) is frozen
            self._c_spec_rounds = reg.counter(
                "serve_spec_rounds_total", "speculative draft+verify rounds")
            self._c_spec_proposed = reg.counter(
                "serve_spec_proposed_tokens_total",
                "draft tokens proposed to the verifier")
            self._c_spec_accepted = reg.counter(
                "serve_spec_accepted_tokens_total",
                "draft tokens accepted by the target")
        self._h_ttft = reg.histogram(
            "serve_ttft_seconds", LATENCY_BUCKETS,
            "arrival -> first generated token")
        self._h_step = reg.histogram(
            "serve_decode_step_seconds", LATENCY_BUCKETS,
            "steady-state decode step wall time (inter-token latency)")
        # SLO accounting: per-request TPOT / end-to-end latency observed at
        # _finish(), and goodput as a callback gauge over the finished list
        # (reset_metrics() clears the list, so the gauge resets with it)
        self._h_tpot = reg.histogram(
            "serve_tpot_seconds", LATENCY_BUCKETS,
            "per-request mean time per output token after the first")
        self._h_e2e = reg.histogram(
            "serve_request_e2e_seconds", LATENCY_BUCKETS,
            "arrival -> request completion")
        reg.gauge("serve_slo_goodput",
                  "fraction of finished requests meeting the TTFT/TPOT "
                  "SLOs (1.0 with no SLO set or nothing finished)",
                  fn=self._slo_goodput)
        reg.gauge("serve_running_requests", "requests in the decode batch",
                  fn=lambda: len(self.scheduler.running))
        reg.gauge("serve_decode_compiles", "decode jit cache entries",
                  fn=self.decode_compile_count)
        reg.gauge("serve_prefill_compiles", "prefill jit cache entries",
                  fn=self.prefill_compile_count)
        reg.gauge("serve_warmup_seconds", "wall time spent in warmup()",
                  fn=lambda: self._warmup_seconds)
        reg.gauge("serve_post_warmup_compiles",
                  "decode+prefill jit compiles not covered by warmup()",
                  fn=self.post_warmup_compiles)
        m, cd = model, compute_dtype
        self._prefill = jax.jit(
            lambda p, tk, c, **kw: m.prefill(p, tk, c, ctx=ctx,
                                             compute_dtype=cd, **kw))
        self._decode = jax.jit(
            lambda p, tk, c, pos: m.decode_step(p, tk, c, pos, ctx=ctx,
                                                compute_dtype=cd))
        # page stores are donated so XLA writes the new token in place
        # instead of copying every page each step
        self._decode_paged = jax.jit(
            lambda p, tk, c, pos, bt: m.decode_step(
                p, tk, c, pos, ctx=ctx, compute_dtype=cd, block_tables=bt),
            donate_argnums=(2,))
        if chunk_ok:
            # the gathered suffix-prefill cache is the largest transient in
            # the serving path; donate it so XLA updates it in place instead
            # of holding input + output copies alive
            self._prefill_chunk = jax.jit(
                lambda p, tk, c, pos, lens: m.prefill_chunk(
                    p, tk, c, pos, lens, ctx=ctx, compute_dtype=cd),
                donate_argnums=(2,))
        else:
            self._prefill_chunk = None
        if self.prefill_kernel:
            # page stores donated, like decode: the suffix K/V scatter and
            # the chunked-prefill kernel update the pages in place
            self._prefill_chunk_paged = jax.jit(
                lambda p, tk, c, pos, lens, bt: m.prefill_chunk(
                    p, tk, c, pos, lens, ctx=ctx, compute_dtype=cd,
                    block_tables=bt),
                donate_argnums=(2,))
        else:
            self._prefill_chunk_paged = None
        self._sample = jax.jit(_sample_rows)
        if self._spec:
            spec_steps = self.spec_k + 1

            def _draft_scan(p, tok, cache, pos, bt, temps, seeds, offs):
                # ONE dispatch proposes the whole k-token draft run: the
                # scan feeds the last committed token then each proposal
                # back in, sampling in-scan (keys derived in-graph from the
                # request seeds, folded per output index — preemption-safe
                # and decorrelated from the non-spec decode keys). One extra
                # step (spec_steps = k + 1) writes the last proposal's K/V
                # so a fully-accepted round leaves no hole in the draft
                # cache; its sampled token is discarded.
                base = jax.vmap(lambda s: jax.random.fold_in(
                    jax.random.PRNGKey(s), _DRAFT_FOLD))(seeds)

                def body(carry, i):
                    tok_c, pos_c, cache_c = carry
                    logits, cache_c = m.decode_step(
                        p, tok_c, cache_c, pos_c, ctx=ctx, compute_dtype=cd,
                        block_tables=bt)
                    keys = jax.vmap(jax.random.fold_in)(base, offs + i)
                    nxt = _sample_rows(logits, temps, keys)
                    return (nxt[:, None], pos_c + 1, cache_c), (nxt, logits)

                (_, _, cache), (props, logits) = jax.lax.scan(
                    body, (tok, pos, cache), jnp.arange(spec_steps))
                return props, logits, cache

            self._spec_draft = jax.jit(_draft_scan, donate_argnums=(2,))

            def _verify_fn(p, tk, c, pos, lens, bt):
                logits, c = m.verify_chunk(p, tk, c, pos, lens, ctx=ctx,
                                           compute_dtype=cd, block_tables=bt)
                # greedy argmax computed in-graph so greedy rounds transfer
                # (B, k+1) ints, not (B, k+1, vocab) logits
                return logits, jnp.argmax(logits, -1).astype(jnp.int32), c

            self._verify = jax.jit(_verify_fn, donate_argnums=(2,))
        else:
            self._spec_draft = None
            self._verify = None

    # ------------------------------------------------------------------ API
    def submit(self, prompt_tokens, max_new_tokens: int, *,
               temperature: float = 0.0, seed: int = 0,
               eos_id: Optional[int] = None,
               extras: Optional[Dict] = None,
               stream_callback: Optional[Callable] = None) -> int:
        """Enqueue one request; returns its id. ``prompt_tokens``: (T0,) ints;
        ``extras``: per-request model inputs shaped (1, ...) — whisper frames,
        vlm vision_embeds. ``stream_callback`` receives a ``StreamEvent`` per
        emitted token (on the detokenize worker thread unless
        ``async_detok=False``)."""
        prompt = np.asarray(prompt_tokens, np.int32).reshape(-1)
        vis = 0
        cfg = getattr(self.model, "cfg", None)
        if (extras and "vision_embeds" in extras and cfg is not None
                and getattr(cfg, "family", "") == "vlm"):
            vis = extras["vision_embeds"].shape[1]
        req = Request(req_id=self._next_id, prompt=prompt,
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      seed=seed, eos_id=eos_id, extras=extras, vis_offset=vis,
                      cacheable=self._chunk_ok and not extras and vis == 0,
                      stream_callback=stream_callback)
        if self._spec and not req.cacheable:
            raise ValueError(
                "speculative decoding serves text-only chunked-prefill "
                "requests (no extras / vision prefixes)")
        # speculative verify transiently writes up to spec_k positions past
        # the budget before rollback — the same headroom admission reserves
        need = self.pool.blocks_for(req.cache_budget()
                                    + (self.spec_k if self._spec else 0))
        if need > self.pool.usable_blocks:
            raise ValueError(
                f"request needs {need} blocks ({req.cache_budget()} cache "
                f"positions) but the pool only has {self.pool.usable_blocks} "
                f"({self.pool.num_blocks} x {self.block_size}-token blocks, "
                "one reserved); raise --num-blocks/--block-size")
        self._next_id += 1
        if self._start_time is None:
            self._start_time = req.arrival_time
        self.scheduler.submit(req)
        if self.flight is not None:
            self.flight.record("submit", req_id=req.req_id,
                               prompt_tokens=int(prompt.size),
                               max_new_tokens=int(max_new_tokens))
        return req.req_id

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def step(self) -> List[Request]:
        """Admit + prefill joiners (same-length-bucket suffixes batched into
        one jitted call), run one decode step over the running batch; returns
        the requests that finished during this step. A raising step dumps
        the postmortem bundle (when a flight recorder is attached) before
        propagating."""
        self._step_idx += 1
        if self.flight is not None:
            self.flight.begin_step(self._step_idx)
        try:
            done = self._step_inner()
        except Exception as e:
            if self.flight is not None:
                self.flight.record("step_exception", error=repr(e))
                self.dump_postmortem("step_exception")
            raise
        self.last_step_time = time.perf_counter()
        return done

    def _step_inner(self) -> List[Request]:
        if self._recalib is not None:
            # between-steps hook: applies staged hot-swaps first, so a swap
            # always lands on a step boundary, never mid-dispatch
            self._recalib.on_step(self)
        done: List[Request] = []
        admitted = self.scheduler.admit()
        groups: Dict[int, list] = {}
        for req in admitted:
            if not req.cacheable:
                self._prefill_request(req)            # extras / hybrid archs
                continue
            # allocate (and thereby look up the cached prefix) once; the
            # suffix length both picks the batch group and feeds the prefill
            toks = req.prefill_tokens()
            cached = self.pool.alloc(req.req_id, len(toks), tokens=toks)
            if self._spec:
                # lockstep pools: the mirrored call sequence keeps the draft
                # registry identical, so hits (and suffix shapes) match
                dcached = self.draft_pool.alloc(req.req_id, len(toks),
                                                tokens=toks)
                assert dcached == cached, "draft pool diverged from target"
            self._c_prompt_tokens.inc(len(toks))
            self._c_prefix_hit_tokens.inc(cached)
            if self.flight is not None and cached:
                self.flight.record("prefix_hit", req_id=req.req_id,
                                   cached_tokens=int(cached))
            if self._recalib is not None:
                # capture rides the admission path: the recalibrator replays
                # exactly the tokens this prefill is about to compute over
                self._recalib.on_prefill(self, req)
            groups.setdefault(
                self._bucket_prefill(len(toks) - cached),
                []).append((req, toks, cached))
        for _, group in sorted(groups.items()):
            self._prefill_batch(group)
        for req in admitted:
            if req.done:
                self._finish(req)
                done.append(req)
        running = list(self.scheduler.running)
        if running:
            done.extend(self._spec_decode_step(running) if self._spec
                        else self._decode_step(running))
        return done

    def fork(self, req_id: int, *, temperature: Optional[float] = None,
             seed: Optional[int] = None) -> int:
        """Clone a running request mid-generation (best-of-n sampling): the
        child shares the parent's cache blocks copy-on-write — the first
        divergent token write into the shared tail block copies just that
        block. Returns the child's request id."""
        parent = next((r for r in self.scheduler.running
                       if r.req_id == req_id), None)
        if parent is None:
            raise ValueError(f"request {req_id} is not running")
        if len(self.scheduler.running) >= self.scheduler.max_running:
            raise ValueError("running set full; cannot fork")
        if seed is None:
            # derive a distinct, deterministic child seed by folding the
            # child's req_id into the parent's: defaulting to parent.seed
            # would replay the parent's exact trajectory at temperature > 0,
            # making best-of-n forks identical. Passing seed explicitly
            # (including parent.seed) keeps the old behavior.
            seed = parent.seed ^ ((0x9E3779B9 * (self._next_id + 1))
                                  & 0x7FFFFFFF)
        child = Request(
            req_id=self._next_id, prompt=parent.prompt.copy(),
            max_new_tokens=parent.max_new_tokens,
            temperature=parent.temperature if temperature is None
            else temperature,
            seed=seed,
            eos_id=parent.eos_id, extras=parent.extras,
            vis_offset=parent.vis_offset, cacheable=parent.cacheable)
        self._next_id += 1
        child.out_tokens = list(parent.out_tokens)
        child.cache_len = parent.cache_len
        # the child continues the parent's lifecycle: keep both timestamps
        # so its TTFT equals the parent's (arrival defaulted to the fork
        # instant, which would make first_token - arrival negative)
        child.arrival_time = parent.arrival_time
        child.first_token_time = parent.first_token_time
        self.pool.fork(parent.req_id, child.req_id)
        if self._spec:
            self.draft_pool.fork(parent.req_id, child.req_id)
        self.scheduler.adopt(child)
        if self.flight is not None:
            self.flight.record("fork", req_id=child.req_id,
                               parent=parent.req_id,
                               at_tokens=len(child.out_tokens))
        return child.req_id

    # ------------------------------------------------------- recalibration
    def attach_recalibrator(self, worker) -> None:
        """Install a live-traffic recalibrator (serve/recalibrate.py's
        ``RecalibWorker``): every ``step()`` calls its ``on_step`` (which
        applies staged hot-swaps and polls the bound gates), admission
        routes sampled prefill streams into its calibrator, and the
        ``serve_recalib_*`` series join the registry. Registered only when
        attached — the base registry schema (docs/observability.md,
        tests/test_obs.py) is frozen, same contract as the spec-only
        series."""
        self._recalib = worker
        worker._engine = self      # reject-path flight/postmortem wiring
        reg = self.registry
        worker.bind_metrics(
            swaps=reg.counter("serve_recalib_swaps_total",
                              "factor hot-swaps applied to the live engine"),
            sampled=reg.counter("serve_recalib_sampled_requests_total",
                                "requests sampled into traffic calibration"),
            tokens=reg.counter("serve_recalib_captured_tokens_total",
                               "served token positions streamed into "
                               "calibration"))
        reg.gauge("serve_recalib_tokens_seen_min",
                  "min calibration tokens streamed over target layers",
                  fn=worker.min_tokens_seen)
        reg.gauge("serve_recalib_bound_clearance",
                  "min tokens_seen / (min_token_factor x n) over target "
                  "layers; the data gate clears at >= 1",
                  fn=worker.clearance)
        reg.gauge("serve_recalib_residual_excess",
                  "worst residual/bound ratio of the last recompression",
                  fn=lambda: worker.last_excess)

    def hot_swap(self, params, draft_params=None) -> None:
        """Swap refreshed factors into the live engine between steps — no
        drain, no retrace. The new pytree must match the live one exactly
        (treedef + per-leaf shape/dtype): params are traced jit *arguments*
        (only caches are donated), so a value-only swap hits every existing
        jit cache entry and ``post_warmup_compiles`` stays 0. In-flight
        requests keep their KV pages; their next decode step simply runs
        the new weights."""
        def _check(name, old, new):
            to, tn = jax.tree.structure(old), jax.tree.structure(new)
            if to != tn:
                raise ValueError(f"hot_swap: {name} treedef mismatch "
                                 f"(rank-unstable recompression?)")
            for lo, ln in zip(jax.tree.leaves(old), jax.tree.leaves(new)):
                so, sn = jnp.shape(lo), jnp.shape(ln)
                do = jnp.result_type(lo)
                dn = jnp.result_type(ln)
                if so != sn or do != dn:
                    raise ValueError(
                        f"hot_swap: {name} leaf changed {so}/{do} -> "
                        f"{sn}/{dn}; swaps must be shape/dtype-stable")
        if draft_params is not None and not self._spec:
            raise ValueError("hot_swap: draft_params given but the engine "
                             "is not in speculative mode")
        _check("params", self.params, params)
        if draft_params is not None:
            _check("draft_params", self.draft_params, draft_params)
        with trace.span("serve.recalib_swap",
                        draft=draft_params is not None):
            self.params = params
            if draft_params is not None:
                self.draft_params = draft_params
        self._swap_epoch += 1
        if self.flight is not None:
            self.flight.record("recalib_swap", epoch=self._swap_epoch,
                               draft=draft_params is not None,
                               in_flight=len(self.scheduler.running))

    def stream(self) -> Iterator[Request]:
        """Drive steps until the queue drains, yielding finished requests.
        With the async pipeline on, a yielded request's detokenized ``text``
        and callbacks may still be in flight — ``flush_stream()`` (which
        ``run()`` calls) waits for them."""
        while self.has_work():
            yield from self.step()

    def flush_stream(self) -> None:
        """Block until every emitted token's detokenize/callback work has
        been delivered by the background worker (no-op when synchronous)."""
        if self._detok is not None:
            self._detok.flush()

    def run(self) -> List[Request]:
        out = list(self.stream())
        self.flush_stream()
        return out

    def run_offline(self, requests, *, sort_by_length: bool = True
                    ) -> List[Request]:
        """MLPerf-style offline batch-inference lane for throughput-bound
        workloads (latency does not matter, tok/s/$ does).

        ``requests``: a sequence of ``(prompt_tokens, max_new_tokens)``
        pairs or dicts of ``submit()`` kwargs. Everything is enqueued up
        front, sorted by prompt length (longest first) so prompts landing
        in the same suffix-length bucket are admitted together and pack
        into shared batched prefill calls; the engine then drives itself to
        drain and flushes the stream pipeline. Returns the finished
        ``Request`` objects in *input* order."""
        norm = []
        for r in requests:
            if isinstance(r, dict):
                norm.append(dict(r))
            else:
                prompt, n = r
                norm.append({"prompt_tokens": prompt, "max_new_tokens": n})
        order = list(range(len(norm)))
        if sort_by_length:
            order.sort(key=lambda i: -len(
                np.asarray(norm[i]["prompt_tokens"]).reshape(-1)))
        with trace.span("serve.run_offline", requests=len(norm)):
            ids = {i: self.submit(**norm[i]) for i in order}
            while self.has_work():
                self.step()
            self.flush_stream()
        by_id = {r.req_id: r for r in self.finished}
        return [by_id[ids[i]] for i in range(len(norm))]

    # -------------------------------------------------------------- warm start
    def warmup_signatures(self, max_len: int):
        """Enumerate every jit signature a trace whose per-request cache
        need stays within ``max_len`` positions can hit.

        Decode: sig ``(b_pad, nb_pad, paged_kernel)`` — every batch bucket
        crossed with every power-of-two block envelope up to the largest a
        ``max_len``-position table can produce (capped by the pool, which a
        real table can never exceed). Chunked prefill: sig ``(b_pad, l_pad,
        nb_pad)`` — for each suffix-length bucket, the shortest suffix that
        maps to it bounds how high a block-aligned cached-prefix offset can
        sit underneath it (``start + suffix <= max_len``), and each
        reachable offset yields one block envelope; without the prefix
        cache the offset is always 0. Returns ``(decode_sigs,
        prefill_sigs)`` as lists of those tuples. In speculative mode the
        decode sigs describe the draft-scan + verify rounds, whose block
        envelope covers the ``spec_k`` transient tail positions a verify
        round writes past the budget."""
        span = max_len + (self.spec_k if self._spec else 0)
        nb_cap = _pow2_at_least(min(self.pool.blocks_for(span),
                                    self.pool.usable_blocks))
        decode = []
        for b in self.bucket_sizes:
            nb = 1
            while nb <= nb_cap:
                decode.append((b, nb, self.paged_kernel))
                nb *= 2
        prefill = []
        if self._chunk_ok:
            l_buckets = sorted({self._bucket_prefill(l)
                                for l in range(1, max_len + 1)})
            prev = 0
            for l_pad in l_buckets:
                len_min = prev + 1          # shortest suffix in this bucket
                prev = l_pad
                if self.prefix_cache:
                    start_max = ((max_len - len_min) // self.block_size
                                 ) * self.block_size
                    starts = range(0, start_max + 1, self.block_size)
                else:
                    starts = (0,)
                nbs = sorted({_pow2_at_least(self.pool.blocks_for(s + l_pad))
                              for s in starts})
                for b in self.bucket_sizes:
                    for nb in nbs:
                        prefill.append((b, l_pad, nb))
        return decode, prefill

    def warmup(self, *, max_len: Optional[int] = None) -> Dict[str, float]:
        """Pre-compile every reachable jit signature against the trash page
        so no admissible request ever waits on XLA: executes (not just
        AOT-lowers — execution is what populates the jit dispatch cache)
        one all-padding call per decode/prefill signature from
        ``warmup_signatures(max_len)``, warms the row sampler at each batch
        bucket and the pool's maintenance jits, and seeds the signature
        sets so the first real step is steady-state for the throughput
        timers. ``max_len`` bounds the worst-case per-request cache
        positions (prompt + generated + vision prefix) to warm for;
        defaults to — and is capped at — pool capacity. Re-running after
        traffic (or with a larger ``max_len``) only compiles what is
        missing. Returns a summary dict; wall time accumulates into
        ``metrics()["warmup_seconds"]``."""
        cap = self.pool.usable_blocks * self.block_size
        max_len = cap if max_len is None else min(max_len, cap)
        t0 = time.perf_counter()
        decode_sigs, prefill_sigs = self.warmup_signatures(max_len)
        with trace.span("serve.warmup", max_len=max_len,
                        decode_sigs=len(decode_sigs),
                        prefill_sigs=len(prefill_sigs)):
            span = max_len + (self.spec_k if self._spec else 0)
            self.pool.warm(self.pool.blocks_for(span))
            if self._spec:
                self.draft_pool.warm(self.draft_pool.blocks_for(span))
            for b, nb, _ in decode_sigs:
                if self._spec:
                    self._warm_spec(b, nb)
                else:
                    self._warm_decode(b, nb)
            for b, l, nb in prefill_sigs:
                self._warm_prefill(b, l, nb)
        self._warmed_decode = self.decode_compile_count()
        self._warmed_prefill = self.prefill_compile_count()
        self.warmed = True                  # /healthz readiness flips here
        dt = time.perf_counter() - t0
        self._warmup_seconds += dt
        return {"warmup_seconds": dt, "max_len": float(max_len),
                "decode_signatures": float(len(decode_sigs)),
                "prefill_signatures": float(len(prefill_sigs))}

    def post_warmup_compiles(self) -> int:
        """Decode+prefill jit compiles beyond what ``warmup()`` covered —
        the zero-stall invariant: 0 after warmup under admissible traffic
        (before any warmup it simply counts all compiles)."""
        return ((self.decode_compile_count() - self._warmed_decode)
                + (self.prefill_compile_count() - self._warmed_prefill))

    def _warm_decode(self, b: int, nb: int) -> None:
        """Execute one decode step at signature ``(b, nb)`` with zero rows:
        all-trash tables/slots, so the in-place page writes land in the
        trash page and no real state is touched."""
        sig = (b, nb, self.paged_kernel)
        if sig in self._decode_shapes:
            return
        self._decode_shapes.add(sig)
        tok = jnp.zeros((b, 1), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        if self.paged_kernel:
            tables = self.pool.padded_tables([], rows=b, blocks=nb)
            cache = self.pool.paged_cache([], rows=b)
            logits, cache = self._decode_paged(self.params, tok, cache, pos,
                                               tables)
            self.pool.absorb_paged([], cache, rows=b)
        else:
            cache = self.pool.gather_batch([], rows=b, blocks=nb)
            logits, cache = self._decode(self.params, tok, cache, pos)
            self.pool.scatter_token([], cache, pos, rows=b, blocks=nb)
        self._warm_sample(jax.block_until_ready(logits), b)

    def _warm_spec(self, b: int, nb: int) -> None:
        """Execute one speculative round — draft scan with the draft params
        against the draft pool, then the verifier with the target params —
        at signature ``(b, nb)`` with zero rows (all-trash tables)."""
        sig = (b, nb, self.paged_kernel)
        if sig in self._spec_shapes:
            return
        self._spec_shapes.add(sig)
        k = self.spec_k
        tok = jnp.zeros((b, 1), jnp.int32)
        pos = jnp.zeros((b,), jnp.int32)
        temps = jnp.zeros((b,), jnp.float32)
        seeds = jnp.zeros((b,), jnp.uint32)
        offs = jnp.zeros((b,), jnp.int32)
        vtok = jnp.zeros((b, k + 1), jnp.int32)
        lens = jnp.full((b,), k + 1, jnp.int32)
        # the draft always runs gathered (see _spec_decode_step); only the
        # verifier's read path follows the paged_kernel knob
        dcache = self.draft_pool.gather_batch([], rows=b, blocks=nb)
        props, _, dcache = self._spec_draft(
            self.draft_params, tok, dcache, pos, None, temps, seeds, offs)
        self.draft_pool.scatter_suffix([], dcache, [], [], rows=b,
                                       blocks=nb)
        if self.paged_kernel:
            tables = self.pool.padded_tables([], rows=b, blocks=nb)
            cache = self.pool.paged_cache([], rows=b)
            _, g, cache = self._verify(self.params, vtok, cache, pos, lens,
                                       tables)
            self.pool.absorb_paged([], cache, rows=b)
        else:
            cache = self.pool.gather_batch([], rows=b, blocks=nb)
            _, g, cache = self._verify(self.params, vtok, cache, pos, lens,
                                       None)
            self.pool.scatter_suffix([], cache, [], [], rows=b, blocks=nb)
        jax.block_until_ready((props, g))

    def _warm_prefill(self, b: int, l: int, nb: int) -> None:
        """Execute one batched suffix prefill at signature ``(b, l, nb)``
        with zero rows (per-row lengths 1, offsets 0, all-trash tables).
        In speculative mode the same signature also runs with the draft
        params against the draft pool — a different params pytree is a
        separate entry in the same jit cache."""
        sig = (b, l, nb)
        if sig not in self._prefill_shapes:
            self._prefill_shapes.add(sig)
            tok = jnp.zeros((b, l), jnp.int32)
            pos = jnp.zeros((b,), jnp.int32)
            ln = jnp.ones((b,), jnp.int32)
            if self.prefill_kernel:
                tables = self.pool.padded_tables([], rows=b, blocks=nb)
                cache = self.pool.paged_cache([], rows=b)
                logits, cache = self._prefill_chunk_paged(
                    self.params, tok, cache, pos, ln, tables)
                self.pool.absorb_paged([], cache, rows=b)
            else:
                cache = self.pool.gather_batch([], rows=b, blocks=nb)
                logits, cache = self._prefill_chunk(self.params, tok, cache,
                                                    pos, ln)
                self.pool.scatter_suffix([], cache, [], [], rows=b, blocks=nb)
            self._warm_sample(jax.block_until_ready(logits), b)
        if self._spec and sig not in self._draft_prefill_shapes:
            self._draft_prefill_shapes.add(sig)
            tok = jnp.zeros((b, l), jnp.int32)
            pos = jnp.zeros((b,), jnp.int32)
            ln = jnp.ones((b,), jnp.int32)
            if self.prefill_kernel:
                dtables = self.draft_pool.padded_tables([], rows=b, blocks=nb)
                dcache = self.draft_pool.paged_cache([], rows=b)
                dlogits, dcache = self._prefill_chunk_paged(
                    self.draft_params, tok, dcache, pos, ln, dtables)
                jax.block_until_ready(dlogits)
                self.draft_pool.absorb_paged([], dcache, rows=b)
            else:
                dcache = self.draft_pool.gather_batch([], rows=b, blocks=nb)
                dlogits, dcache = self._prefill_chunk(self.draft_params, tok,
                                                      dcache, pos, ln)
                jax.block_until_ready(dlogits)
                self.draft_pool.scatter_suffix([], dcache, [], [], rows=b,
                                               blocks=nb)

    def _warm_sample(self, logits, b: int) -> None:
        """Warm the row sampler at batch bucket ``b`` (its jit signature
        depends only on the batch, which the warm call's real logits carry)."""
        temps = jnp.zeros((b,), jnp.float32)
        keys = jnp.stack([jax.random.PRNGKey(0)] * b)
        jax.block_until_ready(self._sample(logits, temps, keys))

    def generate(self, prompt_tokens, max_new_tokens: int, *,
                 extras: Optional[Dict] = None, temperature: float = 0.0,
                 seed: int = 0, **_) -> jnp.ndarray:
        """Fixed-batch convenience wrapper matching ``ServeEngine.generate``:
        submits every row, runs to completion, reassembles (B, T0+new)."""
        b, t0 = prompt_tokens.shape
        prompts = np.asarray(prompt_tokens, np.int32)
        ids = []
        for i in range(b):
            ex = None
            if extras:
                ex = {k: v[i:i + 1] for k, v in extras.items()}
            ids.append(self.submit(prompts[i], max_new_tokens,
                                   temperature=temperature, seed=seed + i,
                                   extras=ex))
        by_id = {r.req_id: r for r in self.run() if r.req_id in set(ids)}
        rows = []
        for i, rid in enumerate(ids):
            out = np.asarray(by_id[rid].out_tokens, np.int32)
            out = np.pad(out, (0, max_new_tokens - len(out)))   # early EOS
            rows.append(np.concatenate([prompts[i], out]))
        return jnp.asarray(np.stack(rows), jnp.int32)

    def decode_compile_count(self) -> int:
        """Entries in the decode jit compile caches (the recompile counter
        that shape bucketing keeps ≤ the number of shape buckets)."""
        try:
            n = int(self._decode._cache_size()
                    + self._decode_paged._cache_size())
            if self._spec_draft is not None:
                n += int(self._spec_draft._cache_size())
            if self._verify is not None:
                n += int(self._verify._cache_size())
            return n
        except AttributeError:   # older jax: fall back to signatures seen
            return len(self._decode_shapes) + len(self._spec_shapes)

    def prefill_compile_count(self) -> int:
        """Entries in the prefill jit caches: length-bucketed suffix batching
        keeps this ≤ the number of (batch, length, blocks) prefill buckets
        instead of one compile per distinct prompt length."""
        try:
            n = int(self._prefill._cache_size())
            if self._prefill_chunk is not None:
                n += int(self._prefill_chunk._cache_size())
            if self._prefill_chunk_paged is not None:
                n += int(self._prefill_chunk_paged._cache_size())
            return n
        except AttributeError:   # older jax: fall back to signatures seen
            return len(self._prefill_shapes)

    def reset_metrics(self) -> None:
        """Zero everything request-level — the finished list (and with it
        the TTFT samples), the preemption/queue-wait series, timers, and
        hit-rate accounting — while keeping jit caches and the prefix
        registry warm, so steady-state benchmark passes can't leak warmup
        samples. One call resets the whole registry: engine, scheduler and
        pool series all live in ``self.registry`` (callback gauges keep
        reading live state)."""
        self.finished = []
        self._start_time = None
        self.registry.reset()
        for k in self.pool.stats:
            self.pool.stats[k] = 0

    def metrics(self) -> Dict[str, float]:
        """Aggregate serving metrics over finished requests — a
        compatibility view over ``self.registry`` (same keys as before the
        registry existed; ``registry.snapshot()`` is the superset)."""
        fin = self.finished
        decode_s = self._c_decode_seconds.value
        prefill_s = self._c_prefill_seconds.value
        decode = {
            "decode_compiles": self.decode_compile_count(),
            "decode_shapes": len(self._decode_shapes),
            "decode_steps": int(self._c_decode_steps.value),
            # steady-state decode throughput: steps that compiled a new
            # (batch, blocks) signature are excluded from the timer; a trace
            # where the timer never accumulated (every step compiled, e.g.
            # a single-step run) reports 0.0 rather than inf
            "decode_tok_per_s": (self._c_decode_tokens.value / decode_s
                                 if decode_s > 0.0 else 0.0),
            "prefill_compiles": self.prefill_compile_count(),
            "prefill_shapes": len(self._prefill_shapes),
            "prefill_batches": int(self._c_prefill_batches.value),
            # steady-state batched suffix-prefill throughput (compiling
            # signatures excluded, 0.0 when nothing ran post-compile), and
            # which read path produced it: 1.0 = chunked-prefill kernel,
            # 0.0 = gather oracle
            "prefill_tok_per_s": (self._c_prefill_tokens.value / prefill_s
                                  if prefill_s > 0.0 else 0.0),
            "prefill_kernel": float(self.prefill_kernel),
            "prefix_hit_rate": (self._c_prefix_hit_tokens.value /
                                max(self._c_prompt_tokens.value, 1)),
            "prefix_hit_tokens": int(self._c_prefix_hit_tokens.value),
            "cached_blocks": self.pool.cached_blocks,
            "cow_copies": int(self.registry.get(
                "pool_cow_copies_total").value),
            "prefix_evictions": int(self.registry.get(
                "pool_prefix_evictions_total").value),
            "queue_depth": len(self.scheduler.waiting),
            "preemptions": int(self.registry.get(
                "serve_preemptions_total").value),
            "warmup_seconds": self._warmup_seconds,
            "post_warmup_compiles": self.post_warmup_compiles(),
            "slo_goodput": self._slo_goodput(),
        }
        if self._spec:
            # speculative-mode-only keys: the non-spec metrics() schema is
            # frozen (tests/test_obs.py golden keys)
            proposed = self._c_spec_proposed.value
            decode.update({
                "spec_k": float(self.spec_k),
                "spec_rounds": int(self._c_spec_rounds.value),
                "spec_proposed_tokens": int(proposed),
                "spec_accepted_tokens": int(self._c_spec_accepted.value),
                "spec_accept_rate": (self._c_spec_accepted.value / proposed
                                     if proposed > 0 else 0.0),
            })
        if self._recalib is not None:
            # recalibration-only keys, same frozen-schema contract as spec
            w = self._recalib
            decode.update({
                "recalib_swaps": int(w.swaps),
                "recalib_sampled_requests": int(w.cal.sampled_requests),
                "recalib_captured_tokens": int(w.cal.captured_tokens),
                "recalib_clearance": float(w.clearance()),
                "recalib_residual_excess": float(w.last_excess),
            })
        if not fin:
            # TTFT is undefined with nothing finished: None, never NaN —
            # json.dumps(..., allow_nan=False) must accept this dict (the
            # /snapshot endpoint and postmortem bundles serialize it)
            return {"requests": 0, "requests_per_sec": 0.0, "new_tokens": 0,
                    "tokens_per_sec": 0.0, "mean_ttft_s": None,
                    "max_ttft_s": None, **decode}
        ttfts = [r.ttft for r in fin if r.ttft is not None]
        new_tokens = sum(len(r.out_tokens) for r in fin)
        elapsed = max(max(r.finish_time for r in fin) - self._start_time,
                      1e-9)
        return {
            "requests": len(fin),
            "requests_per_sec": len(fin) / elapsed,
            "new_tokens": new_tokens,
            "tokens_per_sec": new_tokens / elapsed,
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else None,
            "max_ttft_s": float(np.max(ttfts)) if ttfts else None,
            **decode,
        }

    # ------------------------------------------------------------ internals
    def _emit_stream(self, req: Request, token: int, done: bool) -> None:
        """Hand one emitted token to the host pipeline: enqueued to the
        background worker (O(1) on the dispatch thread) or delivered inline
        when ``async_detok=False``. Skipped when there is nothing to do —
        no detokenizer and no callback on the request."""
        if self.detokenizer is None and req.stream_callback is None:
            return
        index = len(req.out_tokens) - 1
        if self._detok is not None:
            self._detok.submit(req, token, index, done)
        else:
            deliver(req, token, index, done, self.detokenizer)

    @staticmethod
    def _req_tpot(req: Request) -> Optional[float]:
        """Per-request mean time per output token after the first; None
        until finished or with fewer than two tokens (no interval exists)."""
        if req.first_token_time is None or req.finish_time is None:
            return None
        n = len(req.out_tokens)
        if n < 2:
            return None
        return (req.finish_time - req.first_token_time) / (n - 1)

    def _meets_slo(self, req: Request) -> bool:
        """Did a finished request meet the configured latency SLOs? An
        unset SLO (None) is vacuously met; so is a TPOT SLO on a request
        too short to have one."""
        if self.slo_ttft_s is not None:
            t = req.ttft
            if t is None or t > self.slo_ttft_s:
                return False
        if self.slo_tpot_s is not None:
            tp = self._req_tpot(req)
            if tp is not None and tp > self.slo_tpot_s:
                return False
        return True

    def _slo_goodput(self) -> float:
        """Fraction of finished requests meeting the SLOs (1.0 when nothing
        has finished — goodput degrades from perfect, it doesn't start
        broken)."""
        fin = self.finished
        if not fin:
            return 1.0
        return sum(1 for r in fin if self._meets_slo(r)) / len(fin)

    def dump_postmortem(self, reason: str,
                        path: Optional[str] = None) -> Optional[str]:
        """Write the flight recorder's postmortem bundle (ring tail +
        metrics snapshot + engine config + trace tail); returns the path,
        or None when no recorder is attached. Wired to the failure paths —
        step exceptions, recalib gate rejections — and callable from test
        harnesses (the soak suite dumps on pool-invariant failures)."""
        if self.flight is None:
            return None
        try:
            metrics = self.metrics()
        except Exception:            # never let a broken metric eat the dump
            metrics = {}
        config = {
            "block_size": self.block_size,
            "num_blocks": self.pool.num_blocks,
            "max_running": self.scheduler.max_running,
            "bucket_sizes": list(self.bucket_sizes),
            "prefill_bucket_sizes": list(self.prefill_bucket_sizes),
            "paged_kernel": self.paged_kernel,
            "prefill_kernel": self.prefill_kernel,
            "prefix_cache": self.prefix_cache,
            "spec": self._spec,
            "spec_k": self.spec_k,
            "slo_ttft_s": self.slo_ttft_s,
            "slo_tpot_s": self.slo_tpot_s,
            "compute_dtype": str(self.compute_dtype),
            "cache_dtype": str(self.cache_dtype),
            "step": self._step_idx,
            "swap_epoch": self._swap_epoch,
        }
        return self.flight.dump(reason=reason, metrics=metrics,
                                config=config, path=path)

    def _finish(self, req: Request) -> None:
        self.scheduler.evict(req)
        if self._spec:
            self.draft_pool.free(req.req_id)
        self.finished.append(req)
        self._c_finished.inc()
        self._c_new_tokens.inc(len(req.out_tokens))
        self._h_e2e.observe(req.finish_time - req.arrival_time)
        tpot = self._req_tpot(req)
        if tpot is not None:
            self._h_tpot.observe(tpot)
        if self.flight is not None:
            self.flight.record("finish", req_id=req.req_id,
                               new_tokens=len(req.out_tokens),
                               preemptions=req.preemptions,
                               ttft_s=req.ttft, tpot_s=tpot,
                               slo_ok=self._meets_slo(req))
        if self._recalib is not None:
            # completion capture: the generated inputs (out_tokens[:-1])
            # stream into calibration once the request's tail is known
            self._recalib.on_finish(self, req)

    def _bucket_batch(self, n: int) -> int:
        for b in self.bucket_sizes:
            if b >= n:
                return b
        return n

    def _bucket_prefill(self, n: int) -> int:
        """Suffix-length bucket: explicit sizes if given, else powers of two
        with a floor of 8 (padding a handful of tokens is cheaper than a
        fresh XLA compile per prompt length)."""
        for b in self.prefill_bucket_sizes:
            if b >= n:
                return b
        return max(_pow2_at_least(n), 8)

    def _sample_tokens(self, logits, reqs, pad_to: int = 0) -> np.ndarray:
        """Row-wise sampling; rows past ``len(reqs)`` are bucket padding
        (sampled greedily on garbage logits, discarded by the caller)."""
        pad = max(pad_to - len(reqs), 0)
        temps = jnp.asarray([r.temperature for r in reqs] + [0.0] * pad,
                            jnp.float32)
        keys = jnp.stack([
            jax.random.fold_in(jax.random.PRNGKey(r.seed), len(r.out_tokens))
            for r in reqs] + [jax.random.PRNGKey(0)] * pad)
        return np.asarray(self._sample(logits, temps, keys))[:len(reqs)]

    def _prefill_request(self, req: Request) -> None:
        with trace.span("serve.prefill_request", req_id=req.req_id,
                        tokens=len(req.prompt)):
            tokens = req.prefill_tokens()
            l0 = req.vis_offset + len(tokens)
            self.pool.alloc(req.req_id, l0)
            nb = len(self.pool.table(req.req_id))
            cache = self.model.init_cache(1, nb * self.block_size,
                                          dtype=self.cache_dtype)
            kw = dict(req.extras or {})
            logits, cache = self._prefill(self.params,
                                          jnp.asarray(tokens)[None],
                                          cache, **kw)
            logits = logits[:, -1] if logits.ndim == 3 else logits
            self.pool.scatter_prefill([req.req_id], cache, l0)
            req.cache_len = l0
            tok = int(self._sample_tokens(logits, [req])[0])
            req.out_tokens.append(tok)
            self._emit_stream(req, tok, req.done)
            if req.first_token_time is None:
                req.first_token_time = time.perf_counter()
                self._h_ttft.observe(req.ttft)
                if self.flight is not None:
                    self.flight.record("first_token", req_id=req.req_id,
                                       ttft_s=req.ttft)

    def _prefill_batch(self, group) -> None:
        """One jitted prefill over a same-bucket group of (request, tokens,
        cached-prefix-len) joiners, already allocated by ``step()``: each row
        prefills only the suffix its cached prefix does not cover, at its own
        cache offset, padded to the (batch, suffix-len, blocks) bucket.

        ``prefill_kernel=True`` (the default where supported) hands the
        pool's page stores straight to the jitted ``prefill_chunk`` with the
        per-request block tables: attention scatters the suffix K/V into its
        pages and attends through the indirection
        (``kernels/chunked_prefill.py``); the donated stores flow back via
        ``absorb_paged`` — no gather/scatter of the cache. The gather path
        stays as the in-tree oracle."""
        reqs = [r for r, _, _ in group]
        ids = [r.req_id for r in reqs]
        starts = [cached for _, _, cached in group]
        suffixes = [np.asarray(toks[cached:], np.int32)
                    for _, toks, cached in group]
        lens = [len(s) for s in suffixes]
        l_pad = self._bucket_prefill(max(lens))
        b_pad = self._bucket_batch(len(group))
        nb_pad = _pow2_at_least(max(self.pool.blocks_for(s + l_pad)
                                    for s in starts))
        sig = (b_pad, l_pad, nb_pad)
        if self.flight is not None:
            for r, ln_i in zip(reqs, lens):
                self.flight.record("prefill", req_id=r.req_id,
                                   suffix_tokens=int(ln_i), bucket=l_pad,
                                   batch=len(group))
        fresh = sig not in self._prefill_shapes or (
            self._spec and sig not in self._draft_prefill_shapes)
        self._prefill_shapes.add(sig)
        if self._spec:
            self._draft_prefill_shapes.add(sig)
        if fresh:
            trace.instant("serve.prefill_compile", sig=str(sig))
        tok = np.zeros((b_pad, l_pad), np.int32)
        for i, s in enumerate(suffixes):
            tok[i, :len(s)] = s
        pos = jnp.asarray(starts + [0] * (b_pad - len(group)), jnp.int32)
        ln = jnp.asarray(lens + [1] * (b_pad - len(group)), jnp.int32)
        t0 = time.perf_counter()
        with trace.span("serve.prefill_batch", batch=len(group),
                        tokens=sum(lens), sig=str(sig)):
            if self.prefill_kernel:
                tables = self.pool.padded_tables(ids, rows=b_pad,
                                                 blocks=nb_pad)
                cache = self.pool.paged_cache(ids, rows=b_pad)
                logits, cache = self._prefill_chunk_paged(
                    self.params, jnp.asarray(tok), cache, pos, ln, tables)
                logits = jax.block_until_ready(logits)
                self.pool.absorb_paged(ids, cache, rows=b_pad)
            else:
                cache = self.pool.gather_batch(ids, rows=b_pad, blocks=nb_pad)
                logits, cache = self._prefill_chunk(self.params,
                                                    jnp.asarray(tok),
                                                    cache, pos, ln)
                logits = jax.block_until_ready(logits)
                self.pool.scatter_suffix(ids, cache, starts, lens, rows=b_pad,
                                         blocks=nb_pad)
            if self._spec:
                # the draft prefills the same suffixes at the same offsets
                # into its own pool (logits discarded — the first proposal
                # chains off the target's sampled token)
                with trace.span("serve.spec_draft_prefill", batch=len(group)):
                    if self.prefill_kernel:
                        dtables = self.draft_pool.padded_tables(
                            ids, rows=b_pad, blocks=nb_pad)
                        dcache = self.draft_pool.paged_cache(ids, rows=b_pad)
                        dlogits, dcache = self._prefill_chunk_paged(
                            self.draft_params, jnp.asarray(tok), dcache, pos,
                            ln, dtables)
                        jax.block_until_ready(dlogits)
                        self.draft_pool.absorb_paged(ids, dcache, rows=b_pad)
                    else:
                        dcache = self.draft_pool.gather_batch(
                            ids, rows=b_pad, blocks=nb_pad)
                        dlogits, dcache = self._prefill_chunk(
                            self.draft_params, jnp.asarray(tok), dcache, pos,
                            ln)
                        jax.block_until_ready(dlogits)
                        self.draft_pool.scatter_suffix(
                            ids, dcache, starts, lens, rows=b_pad,
                            blocks=nb_pad)
        if not fresh:                       # steady-state timer: skip compiles
            self._c_prefill_seconds.inc(time.perf_counter() - t0)
            self._c_prefill_tokens.inc(sum(lens))
        self._c_prefill_batches.inc()
        nxt = self._sample_tokens(logits, reqs, pad_to=b_pad)
        now = time.perf_counter()
        for r, start, ln_i, t in zip(reqs, starts, lens, nxt):
            r.cache_len = start + ln_i
            r.out_tokens.append(int(t))
            self._emit_stream(r, int(t), r.done)
            if r.first_token_time is None:
                r.first_token_time = now
                self._h_ttft.observe(r.ttft)
                if self.flight is not None:
                    self.flight.record("first_token", req_id=r.req_id,
                                       ttft_s=r.ttft)
            self.pool.commit(r.req_id, r.prefill_tokens()[:r.cache_len])
            if self._spec:
                self.draft_pool.commit(r.req_id,
                                       r.prefill_tokens()[:r.cache_len])

    def _decode_step(self, running: List[Request]) -> List[Request]:
        # reserve the next position for everyone, preempting the youngest
        # request when the pool runs dry
        while True:
            try:
                for r in running:
                    self.pool.extend(r.req_id, r.cache_len + 1)
                break
            except MemoryError:
                victim = self.scheduler.preempt_youngest()
                running = [r for r in running if r is not victim]
                if not running:
                    raise MemoryError(
                        "block pool too small for a single request")
        ids = [r.req_id for r in running]
        b_real = len(ids)
        # bucket the (batch, blocks) envelope to a closed signature set;
        # padding rows carry pos 0 and all-trash tables/slots
        b_pad = self._bucket_batch(b_real)
        nb_pad = _pow2_at_least(self.pool.max_table_blocks(ids))
        sig = (b_pad, nb_pad, self.paged_kernel)
        fresh = sig not in self._decode_shapes
        self._decode_shapes.add(sig)
        if fresh:
            trace.instant("serve.decode_compile", sig=str(sig))
        tables = self.pool.padded_tables(ids, rows=b_pad, blocks=nb_pad)
        tok = jnp.asarray([[r.out_tokens[-1]] for r in running]
                          + [[0]] * (b_pad - b_real), jnp.int32)
        pos = jnp.asarray([r.cache_len for r in running]
                          + [0] * (b_pad - b_real), jnp.int32)
        t0 = time.perf_counter()
        with trace.span("serve.decode_step", batch=b_real, sig=str(sig)):
            if self.paged_kernel:
                cache = self.pool.paged_cache(ids, rows=b_pad)
                logits, cache = self._decode_paged(self.params, tok, cache,
                                                   pos, tables)
                self.pool.absorb_paged(ids, cache, rows=b_pad)
            else:
                cache = self.pool.gather_batch(ids, rows=b_pad, blocks=nb_pad)
                logits, cache = self._decode(self.params, tok, cache, pos)
                self.pool.scatter_token(ids, cache, pos, rows=b_pad,
                                        blocks=nb_pad)
            logits = jax.block_until_ready(logits)
        self._c_decode_steps.inc()
        if not fresh:                       # steady-state timer: skip compiles
            dt = time.perf_counter() - t0
            self._c_decode_seconds.inc(dt)
            self._c_decode_tokens.inc(b_real)
            self._h_step.observe(dt)
        for r in running:
            r.cache_len += 1
        nxt = self._sample_tokens(logits, running, pad_to=b_pad)
        done = []
        for r, t in zip(running, nxt):
            r.out_tokens.append(int(t))
            self._emit_stream(r, int(t), r.done)
            if (self.prefix_cache and r.cacheable
                    and r.cache_len % self.block_size == 0):
                # a generated block just filled: register it so identical
                # traffic (and this request, if preempted) can reuse it
                self.pool.commit(r.req_id, r.prefill_tokens()[:r.cache_len])
            if r.done:
                self._finish(r)
                done.append(r)
        return done

    def _spec_decode_step(self, running: List[Request]) -> List[Request]:
        """One speculative round over the running set: the draft scan
        proposes ``spec_k`` tokens per request, the target verifies all
        ``spec_k + 1`` positions in one chunked call, accepted tokens (plus
        the target's bonus/resample token) are emitted, and both pools roll
        back to the accepted length (``truncate``).

        Position bookkeeping: a round starts at ``c = cache_len`` with last
        emitted token ``t`` not yet written. The draft writes positions
        ``c .. c+k`` (feeding ``t, d_1 .. d_k``); the verifier writes the
        same span with the same tokens and ``logits[i]`` scores the token
        after position ``c + i``. Appending ``m`` accepted tokens advances
        ``cache_len`` by ``m``, so the last-token-unwritten invariant and
        draft/target lockstep hold for every acceptance count; stale K/V
        past the accepted length sits at positions the next round rewrites
        before any causal mask can read them."""
        k = self.spec_k
        # reserve the full verify span [c, c+k] in both pools, COW-securing
        # every block it covers; preempt the youngest when the pool runs dry
        while True:
            try:
                for r in running:
                    self.pool.extend(r.req_id, r.cache_len + k + 1,
                                     write_start=r.cache_len)
                    self.draft_pool.extend(r.req_id, r.cache_len + k + 1,
                                           write_start=r.cache_len)
                break
            except MemoryError:
                victim = self.scheduler.preempt_youngest()
                if victim is not None:
                    self.draft_pool.free(victim.req_id)
                running = [r for r in running if r is not victim]
                if not running:
                    raise MemoryError(
                        "block pool too small for a single request")
        ids = [r.req_id for r in running]
        b_real = len(ids)
        b_pad = self._bucket_batch(b_real)
        nb_pad = _pow2_at_least(self.pool.max_table_blocks(ids))
        sig = (b_pad, nb_pad, self.paged_kernel)
        fresh = sig not in self._spec_shapes
        self._spec_shapes.add(sig)
        if fresh:
            trace.instant("serve.spec_compile", sig=str(sig))
        pad = b_pad - b_real
        tok = jnp.asarray([[r.out_tokens[-1]] for r in running]
                          + [[0]] * pad, jnp.int32)
        pos = jnp.asarray([r.cache_len for r in running] + [0] * pad,
                          jnp.int32)
        temps = jnp.asarray([r.temperature for r in running] + [0.0] * pad,
                            jnp.float32)
        seeds = jnp.asarray([r.seed & 0x7FFFFFFF for r in running]
                            + [0] * pad, jnp.uint32)
        offs = jnp.asarray([len(r.out_tokens) for r in running] + [0] * pad,
                           jnp.int32)
        starts = [r.cache_len for r in running]
        t0 = time.perf_counter()
        with trace.span("serve.spec_step", batch=b_real, sig=str(sig)):
            with trace.span("serve.spec_draft", batch=b_real):
                # the draft always runs on the gathered contiguous envelope:
                # one pool read before the scan, one suffix write-back after,
                # so the k+1 in-scan steps touch only the (rows, envelope)
                # scratch instead of round-tripping the full page stores per
                # proposal (backends without buffer donation — CPU — rewrite
                # every page per paged call; amortizing that per round
                # instead of per token is most of the speculative speedup)
                dcache = self.draft_pool.gather_batch(ids, rows=b_pad,
                                                      blocks=nb_pad)
                props, dlogits, dcache = self._spec_draft(
                    self.draft_params, tok, dcache, pos, None, temps,
                    seeds, offs)
                self.draft_pool.scatter_suffix(
                    ids, dcache, starts, [k + 1] * b_real, rows=b_pad,
                    blocks=nb_pad)
                props_h = np.asarray(props)          # (k+1, b_pad)
            vtok = np.zeros((b_pad, k + 1), np.int32)
            for i, r in enumerate(running):
                vtok[i, 0] = r.out_tokens[-1]
                vtok[i, 1:] = props_h[:k, i]
            lens = jnp.full((b_pad,), k + 1, jnp.int32)
            with trace.span("serve.spec_verify", batch=b_real):
                if self.paged_kernel:
                    tables = self.pool.padded_tables(ids, rows=b_pad,
                                                     blocks=nb_pad)
                    cache = self.pool.paged_cache(ids, rows=b_pad)
                    vlogits, greedy, cache = self._verify(
                        self.params, jnp.asarray(vtok), cache, pos, lens,
                        tables)
                    self.pool.absorb_paged(ids, cache, rows=b_pad)
                else:
                    cache = self.pool.gather_batch(ids, rows=b_pad,
                                                   blocks=nb_pad)
                    vlogits, greedy, cache = self._verify(
                        self.params, jnp.asarray(vtok), cache, pos, lens,
                        None)
                    self.pool.scatter_suffix(
                        ids, cache, starts, [k + 1] * b_real, rows=b_pad,
                        blocks=nb_pad)
                g = np.asarray(greedy)               # (b_pad, k+1)
        # full distributions cross the host boundary only when some row
        # actually samples; greedy rounds transfer just proposals + argmax
        if any(r.temperature > 0.0 for r in running):
            vlog = np.asarray(vlogits, np.float32)   # (b_pad, k+1, V)
            dlog = np.asarray(dlogits, np.float32)   # (k+1, b_pad, V)
        emitted = 0
        done: List[Request] = []
        for i, r in enumerate(running):
            d = [int(t) for t in props_h[:k, i]]
            r.spec_proposed += k
            self._c_spec_proposed.inc(k)
            if r.temperature <= 0.0:
                n_acc = 0
                while n_acc < k and d[n_acc] == int(g[i, n_acc]):
                    n_acc += 1
                toks = d[:n_acc] + [int(g[i, n_acc])]
            else:
                toks, n_acc = self._spec_accept_sampled(r, d, vlog[i],
                                                        dlog[:, i])
            r.spec_accepted += n_acc
            self._c_spec_accepted.inc(n_acc)
            if self.flight is not None:
                self.flight.record("spec_round", req_id=r.req_id,
                                   proposed=k, accepted=n_acc)
            keep: List[int] = []
            for t in toks:
                if len(r.out_tokens) + len(keep) >= r.max_new_tokens:
                    break
                keep.append(t)
                if r.eos_id is not None and t == r.eos_id:
                    break
            r.cache_len += len(keep)
            # rollback: both pools drop the uncommitted tail blocks the
            # rejected proposals wrote
            self.pool.truncate(r.req_id, r.cache_len)
            self.draft_pool.truncate(r.req_id, r.cache_len)
            for t in keep:
                r.out_tokens.append(t)
                self._emit_stream(r, t, r.done)
            emitted += len(keep)
            if self.prefix_cache and r.cacheable:
                committed = r.prefill_tokens()[:r.cache_len]
                self.pool.commit(r.req_id, committed)
                self.draft_pool.commit(r.req_id, committed)
            if r.done:
                self._finish(r)
                done.append(r)
        self._c_decode_steps.inc()
        self._c_spec_rounds.inc()
        if not fresh:                       # steady-state timer: skip compiles
            dt = time.perf_counter() - t0
            self._c_decode_seconds.inc(dt)
            self._c_decode_tokens.inc(emitted)
            self._h_step.observe(dt)
        return done

    def _spec_accept_sampled(self, r: Request, d: List[int],
                             vlog_row: np.ndarray, dlog_row: np.ndarray):
        """Standard speculative rejection sampling for one temperature>0 row:
        accept ``d_i`` w.p. ``min(1, p_i(d_i)/q_i(d_i))``; on the first
        rejection draw from the residual ``norm(max(p_i - q_i, 0))``; after
        a full accept draw the bonus token from ``p_{k+1}``. Draws are
        seeded per (request seed, fold tag, output index) so a given round
        is reproducible. ``vlog_row``/``dlog_row``: (k+1, V) target/draft
        logits. Returns (tokens_to_append, n_accepted)."""
        k = self.spec_k
        base = len(r.out_tokens)
        invt = 1.0 / r.temperature
        toks: List[int] = []
        for i in range(k):
            p = _softmax_np(vlog_row[i] * invt)
            q = _softmax_np(dlog_row[i] * invt)
            rng = np.random.default_rng(
                [r.seed & 0x7FFFFFFF, _ACCEPT_FOLD, base + i])
            di = d[i]
            if rng.random() * max(float(q[di]), 1e-30) < float(p[di]):
                toks.append(di)
                continue
            res = np.maximum(p - q, 0.0)
            s = float(res.sum())
            probs = res / s if s > 0.0 else p
            toks.append(int(rng.choice(probs.shape[0], p=probs)))
            return toks, i
        p = _softmax_np(vlog_row[k] * invt)
        rng = np.random.default_rng(
            [r.seed & 0x7FFFFFFF, _BONUS_FOLD, base + k])
        toks.append(int(rng.choice(p.shape[0], p=p)))
        return toks, k
