"""Batched serving engine: jitted prefill + decode with KV-cache reuse.

Greedy or temperature sampling; fixed-batch continuous loop (the multi-pod
serving dry-run lowers exactly these step functions). Works for decoder-only,
enc-dec (whisper: frames in, cross-cache built at prefill) and vlm (vision
prefix at prefill).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import CPU_CTX, ParallelCtx


@dataclasses.dataclass
class ServeEngine:
    model: object
    params: object
    ctx: ParallelCtx = CPU_CTX
    compute_dtype: object = jnp.bfloat16
    cache_dtype: object = jnp.bfloat16

    def __post_init__(self):
        m, ctx, cd = self.model, self.ctx, self.compute_dtype
        self._prefill = jax.jit(
            lambda p, tk, c, **kw: m.prefill(p, tk, c, ctx=ctx,
                                             compute_dtype=cd, **kw))
        self._decode = jax.jit(
            lambda p, tk, c, pos: m.decode_step(p, tk, c, pos, ctx=ctx,
                                                compute_dtype=cd))

    def generate(self, prompt_tokens, max_new_tokens: int, *,
                 extras: Optional[Dict] = None, temperature: float = 0.0,
                 seed: int = 0, max_len: Optional[int] = None):
        """prompt_tokens: (B, T_prompt) int32 -> (B, T_prompt+new) int32."""
        b, t0 = prompt_tokens.shape
        total = max_len or (t0 + max_new_tokens)
        cache = self.model.init_cache(b, total, dtype=self.cache_dtype)
        kw = dict(extras or {})
        logits, cache = self._prefill(self.params, prompt_tokens, cache, **kw)
        logits = logits[:, -1] if logits.ndim == 3 else logits
        out = [prompt_tokens]
        key = jax.random.PRNGKey(seed)
        tok = self._sample(logits, temperature, key)
        for i in range(max_new_tokens):
            out.append(tok)
            if i == max_new_tokens - 1:
                break
            pos = jnp.asarray(t0 + i, jnp.int32)
            logits, cache = self._decode(self.params, tok, cache, pos)
            key, sk = jax.random.split(key)
            tok = self._sample(logits, temperature, sk)
        return jnp.concatenate(out, axis=1)

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature)[:, None] \
            .astype(jnp.int32)
