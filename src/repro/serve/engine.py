"""Serving engines.

``ServeEngine`` — the original fixed-batch loop: one synchronized batch, a
dense monolithic KV cache, everything decodes in lockstep. Kept as the
fallback/oracle path.

``ContinuousEngine`` — request-level continuous batching over a paged KV
cache. ``submit()`` enqueues a request; each ``step()`` admits whatever fits
(scheduler + block pool), prefills joiners one at a time into pool blocks,
then runs ONE decode step over the whole running set at per-request
positions (the models' vector-``pos`` decode path), so requests of different
lengths interleave freely and finished requests free their blocks
immediately. Per-request sampling params (greedy + temperature) are applied
row-wise; sampling keys are folded per (seed, output index) so a preempted
request resumes on the same trajectory.

The batch each step is assembled by gathering block tables into exactly the
contiguous pytree ``init_cache`` would have produced, so the existing jitted
``prefill``/``decode_step`` functions run unchanged — under greedy decoding
the continuous engine is token-identical to ``ServeEngine``
(tests/test_serve_continuous.py asserts this).

XLA recompiles when the (batch, blocks-per-request) envelope grows; on TPU
you would pad both to fixed buckets — on the CPU smoke path we keep shapes
honest and eat the compile.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import CPU_CTX, ParallelCtx
from repro.serve.paged_cache import BlockPool
from repro.serve.scheduler import Request, Scheduler


@dataclasses.dataclass
class ServeEngine:
    model: object
    params: object
    ctx: ParallelCtx = CPU_CTX
    compute_dtype: object = jnp.bfloat16
    cache_dtype: object = jnp.bfloat16

    def __post_init__(self):
        m, ctx, cd = self.model, self.ctx, self.compute_dtype
        self._prefill = jax.jit(
            lambda p, tk, c, **kw: m.prefill(p, tk, c, ctx=ctx,
                                             compute_dtype=cd, **kw))
        self._decode = jax.jit(
            lambda p, tk, c, pos: m.decode_step(p, tk, c, pos, ctx=ctx,
                                                compute_dtype=cd))

    def generate(self, prompt_tokens, max_new_tokens: int, *,
                 extras: Optional[Dict] = None, temperature: float = 0.0,
                 seed: int = 0, max_len: Optional[int] = None):
        """prompt_tokens: (B, T_prompt) int32 -> (B, T_prompt+new) int32."""
        b, t0 = prompt_tokens.shape
        kw = dict(extras or {})
        # vlm: the vision prefix occupies the first cache positions, so the
        # cache and the decode write positions are offset by its length
        vis = 0
        cfg = getattr(self.model, "cfg", None)
        if ("vision_embeds" in kw and cfg is not None
                and getattr(cfg, "family", "") == "vlm"):
            vis = kw["vision_embeds"].shape[1]
        total = max_len or (vis + t0 + max_new_tokens)
        cache = self.model.init_cache(b, total, dtype=self.cache_dtype)
        logits, cache = self._prefill(self.params, prompt_tokens, cache, **kw)
        logits = logits[:, -1] if logits.ndim == 3 else logits
        out = [prompt_tokens]
        key = jax.random.PRNGKey(seed)
        tok = self._sample(logits, temperature, key)
        for i in range(max_new_tokens):
            out.append(tok)
            if i == max_new_tokens - 1:
                break
            pos = jnp.asarray(vis + t0 + i, jnp.int32)
            logits, cache = self._decode(self.params, tok, cache, pos)
            key, sk = jax.random.split(key)
            tok = self._sample(logits, temperature, sk)
        return jnp.concatenate(out, axis=1)

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature)[:, None] \
            .astype(jnp.int32)


def _sample_rows(logits, temps, keys):
    """Row-wise sampling: greedy where temp <= 0, categorical otherwise."""
    def one(lg, temp, key):
        greedy = jnp.argmax(lg, axis=-1)
        samp = jax.random.categorical(key, lg / jnp.maximum(temp, 1e-6))
        return jnp.where(temp > 0.0, samp, greedy).astype(jnp.int32)
    return jax.vmap(one)(logits, temps, keys)


class ContinuousEngine:
    """Request-level serving: ``submit()`` / ``step()`` / ``stream()``."""

    def __init__(self, model, params, *, ctx: ParallelCtx = CPU_CTX,
                 compute_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16,
                 block_size: int = 16, num_blocks: int = 512,
                 max_running: int = 8):
        self.model = model
        self.params = params
        self.ctx = ctx
        self.compute_dtype = compute_dtype
        self.cache_dtype = cache_dtype
        self.block_size = block_size
        self.pool = BlockPool(model, num_blocks=num_blocks,
                              block_size=block_size,
                              max_requests=max_running, dtype=cache_dtype)
        self.scheduler = Scheduler(self.pool, max_running=max_running)
        self.finished: List[Request] = []
        self._next_id = 0
        self._start_time: Optional[float] = None
        m, cd = model, compute_dtype
        self._prefill = jax.jit(
            lambda p, tk, c, **kw: m.prefill(p, tk, c, ctx=ctx,
                                             compute_dtype=cd, **kw))
        self._decode = jax.jit(
            lambda p, tk, c, pos: m.decode_step(p, tk, c, pos, ctx=ctx,
                                                compute_dtype=cd))
        self._sample = jax.jit(_sample_rows)

    # ------------------------------------------------------------------ API
    def submit(self, prompt_tokens, max_new_tokens: int, *,
               temperature: float = 0.0, seed: int = 0,
               eos_id: Optional[int] = None,
               extras: Optional[Dict] = None) -> int:
        """Enqueue one request; returns its id. ``prompt_tokens``: (T0,) ints;
        ``extras``: per-request model inputs shaped (1, ...) — whisper frames,
        vlm vision_embeds."""
        prompt = np.asarray(prompt_tokens, np.int32).reshape(-1)
        vis = 0
        cfg = getattr(self.model, "cfg", None)
        if (extras and "vision_embeds" in extras and cfg is not None
                and getattr(cfg, "family", "") == "vlm"):
            vis = extras["vision_embeds"].shape[1]
        req = Request(req_id=self._next_id, prompt=prompt,
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      seed=seed, eos_id=eos_id, extras=extras, vis_offset=vis)
        need = self.pool.blocks_for(req.cache_budget())
        if need > self.pool.usable_blocks:
            raise ValueError(
                f"request needs {need} blocks ({req.cache_budget()} cache "
                f"positions) but the pool only has {self.pool.usable_blocks} "
                f"({self.pool.num_blocks} x {self.block_size}-token blocks, "
                "one reserved); raise --num-blocks/--block-size")
        self._next_id += 1
        if self._start_time is None:
            self._start_time = req.arrival_time
        self.scheduler.submit(req)
        return req.req_id

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def step(self) -> List[Request]:
        """Admit + prefill joiners, run one decode step over the running
        batch; returns the requests that finished during this step."""
        done: List[Request] = []
        for req in self.scheduler.admit():
            self._prefill_request(req)
            if req.done:
                self.scheduler.evict(req)
                self.finished.append(req)
                done.append(req)
        running = list(self.scheduler.running)
        if running:
            done.extend(self._decode_step(running))
        return done

    def stream(self) -> Iterator[Request]:
        """Drive steps until the queue drains, yielding finished requests."""
        while self.has_work():
            yield from self.step()

    def run(self) -> List[Request]:
        return list(self.stream())

    def generate(self, prompt_tokens, max_new_tokens: int, *,
                 extras: Optional[Dict] = None, temperature: float = 0.0,
                 seed: int = 0, **_) -> jnp.ndarray:
        """Fixed-batch convenience wrapper matching ``ServeEngine.generate``:
        submits every row, runs to completion, reassembles (B, T0+new)."""
        b, t0 = prompt_tokens.shape
        prompts = np.asarray(prompt_tokens, np.int32)
        ids = []
        for i in range(b):
            ex = None
            if extras:
                ex = {k: v[i:i + 1] for k, v in extras.items()}
            ids.append(self.submit(prompts[i], max_new_tokens,
                                   temperature=temperature, seed=seed + i,
                                   extras=ex))
        by_id = {r.req_id: r for r in self.run() if r.req_id in set(ids)}
        rows = []
        for i, rid in enumerate(ids):
            out = np.asarray(by_id[rid].out_tokens, np.int32)
            out = np.pad(out, (0, max_new_tokens - len(out)))   # early EOS
            rows.append(np.concatenate([prompts[i], out]))
        return jnp.asarray(np.stack(rows), jnp.int32)

    def metrics(self) -> Dict[str, float]:
        """Aggregate serving metrics over finished requests."""
        fin = self.finished
        if not fin:
            return {"requests": 0, "requests_per_sec": 0.0, "new_tokens": 0,
                    "tokens_per_sec": 0.0, "mean_ttft_s": float("nan"),
                    "max_ttft_s": float("nan"), "preemptions": 0}
        ttfts = [r.ttft for r in fin if r.ttft is not None]
        new_tokens = sum(len(r.out_tokens) for r in fin)
        elapsed = max(max(r.finish_time for r in fin) - self._start_time,
                      1e-9)
        return {
            "requests": len(fin),
            "requests_per_sec": len(fin) / elapsed,
            "new_tokens": new_tokens,
            "tokens_per_sec": new_tokens / elapsed,
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else float("nan"),
            "max_ttft_s": float(np.max(ttfts)) if ttfts else float("nan"),
            "preemptions": sum(r.preemptions for r in fin),
        }

    # ------------------------------------------------------------ internals
    def _sample_tokens(self, logits, reqs) -> np.ndarray:
        temps = jnp.asarray([r.temperature for r in reqs], jnp.float32)
        keys = jnp.stack([
            jax.random.fold_in(jax.random.PRNGKey(r.seed), len(r.out_tokens))
            for r in reqs])
        return np.asarray(self._sample(logits, temps, keys))

    def _prefill_request(self, req: Request) -> None:
        tokens = req.prefill_tokens()
        l0 = req.vis_offset + len(tokens)
        self.pool.alloc(req.req_id, l0)
        nb = len(self.pool.table(req.req_id))
        cache = self.model.init_cache(1, nb * self.block_size,
                                      dtype=self.cache_dtype)
        kw = dict(req.extras or {})
        logits, cache = self._prefill(self.params, jnp.asarray(tokens)[None],
                                      cache, **kw)
        logits = logits[:, -1] if logits.ndim == 3 else logits
        self.pool.scatter_prefill([req.req_id], cache, l0)
        req.cache_len = l0
        tok = int(self._sample_tokens(logits, [req])[0])
        req.out_tokens.append(tok)
        if req.first_token_time is None:
            req.first_token_time = time.perf_counter()

    def _decode_step(self, running: List[Request]) -> List[Request]:
        # reserve the next position for everyone, preempting the youngest
        # request when the pool runs dry
        while True:
            try:
                for r in running:
                    self.pool.extend(r.req_id, r.cache_len + 1)
                break
            except MemoryError:
                victim = self.scheduler.preempt_youngest()
                running = [r for r in running if r is not victim]
                if not running:
                    raise MemoryError(
                        "block pool too small for a single request")
        ids = [r.req_id for r in running]
        cache = self.pool.gather_batch(ids)
        tok = jnp.asarray([[r.out_tokens[-1]] for r in running], jnp.int32)
        pos = jnp.asarray([r.cache_len for r in running], jnp.int32)
        logits, cache = self._decode(self.params, tok, cache, pos)
        self.pool.scatter_token(ids, cache, pos)
        for r in running:
            r.cache_len += 1
        nxt = self._sample_tokens(logits, running)
        done = []
        for r, t in zip(running, nxt):
            r.out_tokens.append(int(t))
            if r.done:
                self.scheduler.evict(r)
                self.finished.append(r)
                done.append(r)
        return done
