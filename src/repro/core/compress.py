"""Model-wide COALA compression driver.

Walks the parameter pytree, and for every compressible linear with a
calibrated R factor solves the context-aware low-rank problem (COALA
Algorithm 1/2, or a baseline for comparison) and swaps ``{"w": ...}`` for the
factored ``{"b_t", "a_t"}`` pair the model substrate executes natively
(including the fused Pallas ``lowrank_linear`` kernel on TPU).

Per-layer μ follows the paper's Eq. (5) with a global λ — essential because
layer norms vary by orders of magnitude across depth (paper Fig. 4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CompressConfig
from repro.core import baselines as bl
from repro.core import coala as coala_lib
from repro.core.theory import optimal_weighted_error
from repro.models.linear import rank_for_ratio

# layer-name roles eligible for compression (paper compresses Q,K,V,O,Up,Down
# projections; embeddings / lm_head / routers / norms / recurrence params stay)
COMPRESSIBLE_KEYS = {"wq", "wk", "wv", "wo", "up", "gate", "down",
                     "in_proj", "out_proj", "ff_up", "ff_down",
                     "w_dkv", "shared"}
MIN_DIM = 32


def rank_for_ratio_dims(d_in: int, d_out: int, ratio: float) -> int:
    return rank_for_ratio(d_in, d_out, ratio)


def compressible(path: Tuple[str, ...], shape, cfg=None) -> bool:
    """Is the linear at ``path`` (to its dict or its 'w' leaf) a target?"""
    names = [str(p) for p in path]
    if names and names[-1] == "w":
        names = names[:-1]
    key = names[-1] if names else ""
    if key not in COMPRESSIBLE_KEYS - {"shared"}:
        return False
    d_in, d_out = shape[-2], shape[-1]
    return min(d_in, d_out) >= MIN_DIM


@dataclasses.dataclass
class LayerReport:
    path: str
    rank: int
    mu: float
    rel_err_weighted: float      # ||(W-W')R^T||/||W R^T||
    params_before: int
    params_after: int
    # attainable minimum of the same ratio (Σ-tail of σ(W Rᵀ), theory.py's
    # optimal_weighted_error / ||W Rᵀ||); nan when the layer had no R factor.
    # obs/numerics.check_compression grades rel_err_weighted against this.
    rel_err_bound: float = float("nan")


def _solve(w_mat, r_factor, rank, ccfg: CompressConfig):
    """Dispatch on method. w_mat: (d_out, d_in) matrix view."""
    if ccfg.method == "coala":
        res = coala_lib.coala_factors(
            w_mat, r_factor=r_factor, rank=rank,
            mu=max(ccfg.mu, 0.0) if ccfg.mu >= 0 else 0.0,
            lam=ccfg.lam if ccfg.mu < 0 else None,
            use_rsvd=ccfg.use_rsvd, rsvd_oversample=ccfg.rsvd_oversample,
            rsvd_power_iters=ccfg.rsvd_power_iters)
        return res.a, res.b, res.mu
    if ccfg.method == "svd":
        a, b = bl.plain_svd(w_mat, rank)
        return a, b, 0.0
    if ccfg.method == "svd_llm":
        gram = r_factor.T @ r_factor
        a, b = bl.svd_llm(w_mat, gram, rank)
        return a, b, 0.0
    if ccfg.method == "svd_llm_v2":
        gram = r_factor.T @ r_factor
        a, b = bl.svd_llm_v2(w_mat, gram, rank)
        return a, b, 0.0
    if ccfg.method == "asvd":
        # diagonal scale from R (mean |col| proxy for mean |activation|)
        a, b = bl.asvd(w_mat, r_factor.T, rank)
        return a, b, 0.0
    raise ValueError(f"unknown method {ccfg.method}")


def compress_params(params, r_factors: Dict[str, jax.Array],
                    ccfg: CompressConfig, rank_map=None):
    """Returns (new_params, [LayerReport...]). ``r_factors`` keys are the
    calibrator paths ('blocks/3/sub0/mixer/wq', ...). ``rank_map`` (adaptive
    allocation, core/rank_alloc.py) overrides the uniform ratio per path."""
    reports = []

    def _compress_experts(node, path):
        """Per-expert COALA (paper's limited-data regime: each expert sees
        only its routed tokens — μ-regularization is load-bearing here).
        Dense stacks (E, d_in, d_out) become factored tuples
        (b_t (E,d_in,r), a_t (E,r,d_out))."""
        p = "/".join(path)
        out = dict(node)
        e_total = node["w_gate"].shape[0]
        for mat, rf_kind in (("w_gate", "in"), ("w_up", "in"),
                             ("w_down", "hid")):
            w_stack = node[mat]
            if isinstance(w_stack, tuple) or w_stack.ndim != 3:
                continue
            bts, ats = [], []
            compressed_any = False
            for e in range(e_total):
                rf = r_factors.get(f"{p}/expert{e}/{rf_kind}")
                w = w_stack[e]
                d_in, d_out = w.shape
                rank = (ccfg.rank if ccfg.rank > 0
                        else rank_for_ratio(d_in, d_out, ccfg.ratio))
                rank = min(rank, min(d_in, d_out))
                if rf is None:
                    # expert never routed to during calibration: keep the
                    # EYM projection (X=I ⇒ μ-regularized limit, Prop. 3)
                    a, b = bl.plain_svd(w.T.astype(jnp.float32), rank)
                else:
                    a, b, mu = _solve(w.T.astype(jnp.float32),
                                      rf.astype(jnp.float32), rank, ccfg)
                    compressed_any = True
                bts.append(b.T.astype(w.dtype))
                ats.append(a.T.astype(w.dtype))
                if rf is None:
                    rel_err = bound = float("nan")
                else:
                    den = jnp.maximum(jnp.linalg.norm(w.T @ rf.T), 1e-9)
                    rel_err = float(
                        jnp.linalg.norm((w.T - a @ b) @ rf.T) / den)
                    bound = float(optimal_weighted_error(
                        w.T.astype(jnp.float32), rf.T.astype(jnp.float32),
                        rank) / den)
                reports.append(LayerReport(
                    path=f"{p}/{mat}/e{e}", rank=rank,
                    mu=0.0, rel_err_weighted=rel_err,
                    params_before=d_in * d_out,
                    params_after=rank * (d_in + d_out),
                    rel_err_bound=bound))
            out[mat] = (jnp.stack(bts), jnp.stack(ats))
        return out

    def walk(node, path):
        if isinstance(node, dict):
            if ("w_gate" in node and not isinstance(node["w_gate"], tuple)
                    and getattr(node["w_gate"], "ndim", 0) == 3
                    and any(k.startswith("/".join(path) + "/expert")
                            for k in r_factors)):
                sub = _compress_experts(node, path)
                # shared experts / router handled by the normal walk below
                return {k: (v if k in ("w_gate", "w_up", "w_down")
                            else walk(v, path + [k]))
                        for k, v in sub.items()}
            if "w" in node and getattr(node["w"], "ndim", 0) == 2:
                p = "/".join(path)
                if p in r_factors and compressible(tuple(path) + ("w",),
                                                   node["w"].shape):
                    w = node["w"]
                    d_in, d_out = w.shape
                    w_mat = w.T.astype(jnp.float32)       # (d_out, d_in)
                    if rank_map is not None and p in rank_map:
                        rank = rank_map[p]
                    else:
                        rank = (ccfg.rank if ccfg.rank > 0
                                else rank_for_ratio(d_in, d_out, ccfg.ratio))
                    rank = min(rank, min(d_in, d_out))
                    r_f = r_factors[p].astype(jnp.float32)
                    a, b, mu = _solve(w_mat, r_f, rank, ccfg)
                    num = jnp.linalg.norm((w_mat - a @ b) @ r_f.T)
                    den = jnp.maximum(jnp.linalg.norm(w_mat @ r_f.T), 1e-9)
                    reports.append(LayerReport(
                        path=p, rank=rank, mu=float(mu),
                        rel_err_weighted=float(num / den),
                        params_before=d_in * d_out,
                        params_after=rank * (d_in + d_out),
                        rel_err_bound=float(optimal_weighted_error(
                            w_mat, r_f.T, rank) / den)))
                    return {"b_t": b.T.astype(w.dtype),
                            "a_t": a.T.astype(w.dtype)}
                return {k: walk(v, path + [k]) for k, v in node.items()}
            return {k: walk(v, path + [k]) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, path + [str(i)]) for i, v in enumerate(node)]
        return node

    new_params = walk(params, [])
    return new_params, reports


def rank_map_from_reports(reports) -> Dict[str, int]:
    """Pin per-layer ranks from a previous compression's reports, keyed by
    full calibrator path. Recompressing with this map is *shape-stable*:
    the new factors have identical shapes/dtypes to the old ones, which is
    what live hot-swaps (serve/recalibrate.py) rely on to hit the serving
    engine's existing jit cache entries. Per-expert rows (path suffix
    '/e<i>') describe stacked expert banks, not standalone linears, and
    are skipped — expert ranks re-derive from the same ccfg."""
    import re
    return {r.path: r.rank for r in reports
            if not re.search(r"/e\d+$", r.path)}


def compress_model(model, params, calibrator, ccfg: CompressConfig, *,
                   rank_map: Optional[Dict[str, int]] = None):
    """End-to-end: calibrator R factors -> compressed params + report.

    The calibrator keys look like 'blocks/2/sub0/mixer/wq'; stacked block
    params are compressed per-layer by slicing rep r, compressing, and
    re-stacking (each rep has its own activations, as in the paper).
    ``rank_map`` (full paths -> rank) overrides both the uniform ratio and
    adaptive allocation — recompression passes pin it from the previous
    reports (``rank_map_from_reports``) so factor shapes stay stable."""
    r_factors = calibrator.r_factors()
    if rank_map is None and getattr(ccfg, "adaptive_rank", False):
        from repro.core.rank_alloc import adaptive_rank_map
        weights = {}

        def collect(node, path):
            if isinstance(node, dict):
                if "w" in node and getattr(node["w"], "ndim", 0) == 2:
                    p = "/".join(path)
                    if p in r_factors and compressible(
                            tuple(path) + ("w",), node["w"].shape):
                        weights[p] = node["w"]
                    return
                for k, v in node.items():
                    collect(v, path + [k])
            elif isinstance(node, list):
                for i, v in enumerate(node):
                    collect(v, path + [str(i)])

        # stacked layers contribute per-rep entries keyed like the calibrator
        for skey in (k for k in ("blocks", "enc", "dec") if k in params):
            n_rep = jax.tree.leaves(params[skey])[0].shape[0]
            for r in range(n_rep):
                collect(jax.tree.map(lambda a: a[r], params[skey]),
                        [skey, str(r)])
        collect({k: v for k, v in params.items()
                 if k not in ("blocks", "enc", "dec")}, [])
        rank_map = adaptive_rank_map(weights, r_factors, ccfg.ratio)
    stacked_keys = [k for k in ("blocks", "enc", "dec") if k in params]

    # split stacked-layer paths ('<key>/<rep>/...') from flat paths
    flat_rf = {p: r for p, r in r_factors.items()
               if p.split("/", 1)[0] not in stacked_keys}
    per_key_rf: Dict[str, Dict[int, Dict[str, jax.Array]]] = {}
    for p, r in r_factors.items():
        head = p.split("/", 1)[0]
        if head in stacked_keys:
            _, rep, rest = p.split("/", 2)
            per_key_rf.setdefault(head, {}).setdefault(int(rep), {})[rest] = r

    reports = []
    new_params = dict(params)
    # non-stacked portions (prefix layers, top-level)
    np_, rep_ = compress_params(
        {k: v for k, v in params.items() if k not in stacked_keys},
        flat_rf, ccfg, rank_map=rank_map)
    new_params.update(np_)
    reports.extend(rep_)

    for skey in stacked_keys:
        blk_rf = per_key_rf.get(skey)
        if not blk_rf:
            continue
        n_rep = jax.tree.leaves(params[skey])[0].shape[0]
        slices = []
        for r in range(n_rep):
            blk = jax.tree.map(lambda a: a[r], params[skey])
            sub_map = None
            if rank_map is not None:
                pre = f"{skey}/{r}/"
                sub_map = {p[len(pre):]: v for p, v in rank_map.items()
                           if p.startswith(pre)}
            nb, rp = compress_params(blk, blk_rf.get(r, {}), ccfg,
                                     rank_map=sub_map)
            for item in rp:
                item.path = f"{skey}/{r}/" + item.path
            reports.extend(rp)
            slices.append(nb)
        new_params[skey] = jax.tree.map(lambda *xs: jnp.stack(xs), *slices)
    return new_params, reports


def compress_model_pair(model, params, calibrator, ccfg: CompressConfig, *,
                        draft_ratio: float):
    """Target + draft compression from ONE calibration pass.

    Self-speculative serving compresses the same model twice — the serving
    target at ``ccfg.ratio`` and a harder-compressed draft at ``draft_ratio``
    — and both solves reuse the calibrator's R factors, so the activation
    pass over the calibration data is paid once. Returns
    ``(target_params, draft_params, target_reports, draft_reports)``."""
    if not 0.0 < draft_ratio < 1.0:
        raise ValueError(f"draft_ratio must be in (0, 1), got {draft_ratio}")
    tparams, treports = compress_model(model, params, calibrator, ccfg)
    dcfg = dataclasses.replace(ccfg, ratio=draft_ratio, rank=0)
    dparams, dreports = compress_model(model, params, calibrator, dcfg)
    return tparams, dparams, treports, dreports


def compression_summary(reports) -> dict:
    before = sum(r.params_before for r in reports)
    after = sum(r.params_after for r in reports)
    errs = [r.rel_err_weighted for r in reports]
    return {"layers": len(reports),
            "params_before": before, "params_after": after,
            "kept_ratio": after / before if before else 1.0,
            "mean_rel_err": float(jnp.mean(jnp.asarray(errs))) if errs else 0.0,
            "max_rel_err": float(jnp.max(jnp.asarray(errs))) if errs else 0.0}
