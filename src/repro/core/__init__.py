"""COALA core: the paper's contribution as a composable JAX library."""
from repro.core.coala import (  # noqa: F401
    CoalaResult,
    coala_factors,
    coala_project,
    coala_alpha_factors,
    eym_truncate,
    mu_from_lambda,
    r_from_x,
    rsvd_left_singvecs,
    weighted_error,
    balanced_split,
)
from repro.core.tsqr import (  # noqa: F401
    RStreamer,
    augment_r_with_mu,
    distributed_tsqr_r,
    gram_chunked,
    qr_r,
    square_r,
    tsqr_sequential,
    tsqr_tree,
)
from repro.core import baselines, theory  # noqa: F401
