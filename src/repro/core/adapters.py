"""PEFT adapter initialization (paper §6.2, Table 4).

Unified through Proposition 4's (XXᵀ)^α family:

  * lora   — random A, zero B (Hu et al.)
  * pissa  — α=0: principal subspace of W itself (Meng et al.)
  * corda  — α=2 via the fragile Gram-inverse form (Remark 1 baseline)
  * coala  — α∈{1,2} inversion-free (the paper's robustified variants)

Each method converts target linears to {"w": W_res, "b_t": Bᵀ, "a_t": Aᵀ}
(dense residual + trainable low-rank adapter — ``linear_apply`` sums them),
and returns a boolean mask marking the trainable adapter leaves.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import baselines as bl
from repro.core import coala as coala_lib
from repro.core.compress import COMPRESSIBLE_KEYS, compressible


def _init_one(w, r_factor, method: str, rank: int, key):
    """w: (d_in, d_out) storage view. Returns (w_res, b_t, a_t)."""
    d_in, d_out = w.shape
    w_mat = w.T.astype(jnp.float32)                    # (d_out, d_in)
    if method == "lora":
        a_t = jnp.zeros((rank, d_out), w.dtype)        # B=0 start
        b_t = (jax.random.normal(key, (d_in, rank), jnp.float32)
               / jnp.sqrt(d_in)).astype(w.dtype)
        return w, b_t, a_t
    if method == "pissa":
        a, b = coala_lib.coala_alpha_factors(w_mat, r_factor=jnp.eye(d_in),
                                             rank=rank, alpha=0.0)
    elif method == "corda":
        gram = r_factor.T @ r_factor
        x_proxy = r_factor.T                           # XXᵀ = RᵀR
        a, b = bl.corda(w_mat, x_proxy, rank)
    elif method.startswith("coala"):
        alpha = float(method.split("_a")[1]) if "_a" in method else 1.0
        a, b = coala_lib.coala_alpha_factors(w_mat, r_factor=r_factor,
                                             rank=rank, alpha=alpha)
    else:
        raise ValueError(method)
    a, b = coala_lib.balanced_split(a, b)
    w_res = (w_mat - a @ b).T.astype(w.dtype)
    return w_res, b.T.astype(w.dtype), a.T.astype(w.dtype)


def _init_flat(params, r_factors, method, rank, key):
    def walk(node, path):
        nonlocal key
        if isinstance(node, dict):
            if "w" in node and getattr(node["w"], "ndim", 0) == 2:
                p = "/".join(path)
                if compressible(tuple(path), node["w"].shape) and (
                        method in ("lora", "pissa") or p in r_factors):
                    key, sk = jax.random.split(key)
                    rf = r_factors.get(p)
                    w_res, b_t, a_t = _init_one(node["w"], rf, method,
                                                rank, sk)
                    return ({"w": w_res, "b_t": b_t, "a_t": a_t},
                            {"w": False, "b_t": True, "a_t": True})
            if isinstance(node, dict):
                out = [walk(v, path + [k]) for k, v in node.items()]
                return ({k: o[0] for k, o in zip(node, out)},
                        {k: o[1] for k, o in zip(node, out)})
        if isinstance(node, list):
            out = [walk(v, path + [str(i)]) for i, v in enumerate(node)]
            return [o[0] for o in out], [o[1] for o in out]
        return node, False

    return walk(params, [])


def init_adapters(params, r_factors: Dict[str, jax.Array], *, method: str,
                  rank: int, seed: int = 0):
    """Returns (new_params, trainable_mask) — mask True on adapter leaves.

    Scanned-block params (stacked leading layer dim) are handled per-rep:
    slice, initialize, re-stack — each layer gets its own subspace/R."""
    key = jax.random.PRNGKey(seed)
    flat_rf = {p: r for p, r in r_factors.items()
               if not p.startswith("blocks/")}
    blk_rf: Dict[int, Dict[str, jax.Array]] = {}
    for p, r in r_factors.items():
        if p.startswith("blocks/"):
            _, rep, rest = p.split("/", 2)
            blk_rf.setdefault(int(rep), {})[rest] = r

    top = {k: v for k, v in params.items() if k != "blocks"}
    new_top, mask_top = _init_flat(top, flat_rf, method, rank, key)
    new_params = dict(new_top)
    mask = dict(mask_top)

    if "blocks" in params:
        n_rep = jax.tree.leaves(params["blocks"])[0].shape[0]
        slices, mask_blk = [], None
        for r in range(n_rep):
            blk = jax.tree.map(lambda a: a[r], params["blocks"])
            nb, mb = _init_flat(blk, blk_rf.get(r, {}), method, rank,
                                jax.random.fold_in(key, r))
            slices.append(nb)
            mask_blk = mb
        new_params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *slices)
        mask["blocks"] = mask_blk
    return new_params, mask


def merge_adapters(params):
    """Fold b_t·a_t back into w (deployment form)."""
    def walk(node):
        if isinstance(node, dict):
            if "w" in node and "b_t" in node:
                w = node["w"] + (node["b_t"] @ node["a_t"]).astype(node["w"].dtype)
                return {"w": w}
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node
    return walk(params)


def mask_grads(grads, mask):
    """Zero gradients on frozen leaves (adapter-only fine-tuning)."""
    return jax.tree.map(lambda g, m: g if m else jnp.zeros_like(g),
                        grads, mask)
