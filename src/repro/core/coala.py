"""COALA: inversion-free, regularized context-aware low-rank approximation.

Implements the paper's core results:

  * Proposition 1 — ``W' = U_r U_rᵀ W`` with U_r the top-r left singular
    vectors of ``W X``. No Gram matrix, no inversion, X arbitrary.
  * Proposition 2 — the same U_r from ``W Rᵀ`` where ``QR = Xᵀ`` (Algorithm 1).
  * Proposition 3 — regularized problem ≡ unregularized with X̃ = [X √μ I]
    (Algorithm 2), with the paper's Eq. (5) per-layer μ selection.
  * Proposition 4 — the (XXᵀ)^α family unifying PiSSA (α=0), COALA (α=1) and
    a robustified CorDA (α=2), used for adapter initialization.

Beyond-paper: a randomized (subspace-iteration) SVD path ``rsvd`` that only
computes the top-r subspace — O(m n r) matmul-only work, MXU-friendly on TPU —
while preserving the inversion-free structure.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import tsqr as tsqr_lib


# ---------------------------------------------------------------------------
# SVD helpers
# ---------------------------------------------------------------------------

def _topk_left_singvecs(m: jax.Array, r: int) -> jax.Array:
    """Top-r left singular vectors of m via full SVD (paper-faithful path)."""
    u, _, _ = jnp.linalg.svd(m, full_matrices=False)
    return u[:, :r]


@partial(jax.jit, static_argnames=("r", "oversample", "power_iters"))
def rsvd_left_singvecs(m: jax.Array, r: int, *, oversample: int = 8,
                       power_iters: int = 2, seed: int = 0) -> jax.Array:
    """Randomized range finder for the top-r left subspace of ``m`` (beyond-paper).

    Halko–Martinsson–Tropp with QR-stabilized power iterations. All the work
    is matmul + thin QR — no Gram matrix of X is ever formed, so the
    inversion-free stability story is preserved (error controlled by
    ``power_iters``; see tests for the accuracy sweep).
    """
    mm, nn = m.shape
    l = min(r + oversample, nn)
    omega = jax.random.normal(jax.random.PRNGKey(seed), (nn, l), m.dtype)
    y = m @ omega                                  # (mm, l)
    q, _ = jnp.linalg.qr(y)
    for _ in range(power_iters):
        z, _ = jnp.linalg.qr(m.T @ q)
        q, _ = jnp.linalg.qr(m @ z)
    b = q.T @ m                                    # (l, nn)
    ub, _, _ = jnp.linalg.svd(b, full_matrices=False)
    u = q @ ub
    return u[:, :r]


# ---------------------------------------------------------------------------
# Algorithm 1 / 2 — the COALA solver
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoalaResult:
    a: jax.Array          # (m, r)
    b: jax.Array          # (r, n)
    mu: float             # μ actually used
    r_factor: jax.Array   # the (possibly μ-augmented) R that was factored

    @property
    def w_approx(self) -> jax.Array:
        return self.a @ self.b


def r_from_x(x: jax.Array, chunk_tokens: int = 0) -> jax.Array:
    """R factor of qr(Xᵀ) for X (n, k); optionally via streaming TSQR chunks."""
    xt = x.T
    if chunk_tokens and xt.shape[0] > chunk_tokens:
        chunks = [xt[i:i + chunk_tokens] for i in range(0, xt.shape[0], chunk_tokens)]
        r = tsqr_lib.tsqr_sequential(chunks)
    else:
        r = tsqr_lib.qr_r(xt)
    return tsqr_lib.square_r(r)


@partial(jax.jit, static_argnames=("r",))
def _factor_from_r(w: jax.Array, r_factor: jax.Array, r: int) -> Tuple[jax.Array, jax.Array]:
    u_r = _topk_left_singvecs(w @ r_factor.T, r)
    return u_r, u_r.T @ w


@partial(jax.jit, static_argnames=("r", "oversample", "power_iters"))
def _factor_from_r_rsvd(w: jax.Array, r_factor: jax.Array, r: int,
                        oversample: int, power_iters: int) -> Tuple[jax.Array, jax.Array]:
    u_r = rsvd_left_singvecs(w @ r_factor.T, r,
                             oversample=oversample, power_iters=power_iters)
    return u_r, u_r.T @ w


def coala_factors(
    w: jax.Array,
    x: Optional[jax.Array] = None,
    *,
    r_factor: Optional[jax.Array] = None,
    rank: int,
    mu: float = 0.0,
    lam: Optional[float] = None,
    use_rsvd: bool = False,
    rsvd_oversample: int = 8,
    rsvd_power_iters: int = 2,
    chunk_tokens: int = 0,
) -> CoalaResult:
    """COALA Algorithm 1/2. Provide either ``x`` (n, k) or a precomputed
    ``r_factor`` (n, n) from the calibration pipeline.

    mu/lam: explicit μ, or λ-driven Eq. (5) selection when ``lam`` is given
    (μ = λ · ||W₀X − WX||²_F / ||W₀ − W||²_F, computed from R only).
    """
    if (x is None) == (r_factor is None):
        raise ValueError("pass exactly one of x / r_factor")
    if r_factor is None:
        r_factor = r_from_x(x, chunk_tokens)
    r_factor = tsqr_lib.square_r(r_factor)

    solve = (partial(_factor_from_r_rsvd, oversample=rsvd_oversample,
                     power_iters=rsvd_power_iters)
             if use_rsvd else _factor_from_r)

    if lam is not None:
        a0, b0 = solve(w, r_factor, rank)
        mu = float(mu_from_lambda(w, a0 @ b0, r_factor, lam))
    if mu > 0.0:
        r_used = tsqr_lib.augment_r_with_mu(r_factor, mu)
    else:
        r_used = r_factor
    a, b = solve(w, r_used, rank)
    return CoalaResult(a=a, b=b, mu=float(mu), r_factor=r_used)


def coala_project(w, x=None, *, r_factor=None, rank: int, **kw) -> jax.Array:
    """Convenience: the rank-r approximation W' itself."""
    res = coala_factors(w, x, r_factor=r_factor, rank=rank, **kw)
    return res.w_approx


@jax.jit
def mu_from_lambda(w: jax.Array, w0: jax.Array, r_factor: jax.Array,
                   lam: float) -> jax.Array:
    """Paper Eq. (5): μ = λ · ||(W₀−W)X||²_F / ||W₀−W||²_F.

    Uses ||(W₀−W)X||_F = ||(W₀−W)Rᵀ||_F (Prop. 2 trick) so no X is needed.
    """
    diff = w0 - w
    num = jnp.sum((diff @ r_factor.T) ** 2)
    den = jnp.sum(diff ** 2)
    return lam * num / jnp.maximum(den, jnp.finfo(w.dtype).tiny)


# ---------------------------------------------------------------------------
# Proposition 4 — the α-family (adapter initialization)
# ---------------------------------------------------------------------------

def alpha_weight_factor(x_or_r: jax.Array, alpha: float, *, is_r: bool = False) -> jax.Array:
    """Return S_α with S_α S_αᵀ = (XXᵀ)^α, computed inversion-free.

    From the SVD of Xᵀ = Q Σ Vᵀ (or of R): (XXᵀ)^{α/2} = V Σ^α Vᵀ.
    α=0 → I (PiSSA), α=1 → (XXᵀ)^{1/2} (COALA), α=2 → XXᵀ (CorDA, robustified:
    formed from singular values of X, never from an explicit Gram matrix).
    """
    mat = x_or_r if is_r else x_or_r.T          # rows = tokens/R-rows, cols = n
    _, s, vt = jnp.linalg.svd(mat, full_matrices=False)
    n = mat.shape[1]
    s_full = jnp.zeros((n,), mat.dtype).at[: s.shape[0]].set(s)
    v = jnp.zeros((n, n), mat.dtype).at[:, : vt.shape[0]].set(vt.T)
    return (v * (s_full ** alpha)[None, :]) @ v.T


def coala_alpha_factors(w: jax.Array, x: Optional[jax.Array] = None, *,
                        r_factor: Optional[jax.Array] = None,
                        rank: int, alpha: float = 1.0,
                        mu: float = 0.0) -> Tuple[jax.Array, jax.Array]:
    """Prop. 4 solution: W' = U_r U_rᵀ W with U_r from SVD(W (XXᵀ)^{α/2}).

    Returns (A, B) = (U_r, U_rᵀ W). For α=1 this coincides with Algorithm 1.
    """
    if (x is None) == (r_factor is None):
        raise ValueError("pass exactly one of x / r_factor")
    if mu < 0.0:
        raise ValueError(f"mu must be non-negative, got {mu}")
    if alpha == 1.0 and mu == 0.0:
        res = coala_factors(w, x, r_factor=r_factor, rank=rank)
        return res.a, res.b
    src = r_factor if r_factor is not None else x
    s_alpha = alpha_weight_factor(src, alpha, is_r=r_factor is not None)
    if mu > 0.0:
        # (XXᵀ)^α + μI via augmented-R of S_α (S_α is symmetric, rows = n)
        s_alpha = tsqr_lib.augment_r_with_mu(tsqr_lib.qr_r(s_alpha), mu).T
    u_r = _topk_left_singvecs(w @ s_alpha, rank)
    return u_r, u_r.T @ w


def balanced_split(a: jax.Array, b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Rebalance (A, B) so both factors have comparable scale (adapter init:
    gradients are better conditioned when ||A col_i|| ≈ ||B row_i||).

    Per index i the scale ``sqrt(||B row_i|| / ||A col_i||)`` moves both
    norms to the geometric mean ``sqrt(||A col_i|| · ||B row_i||)`` for
    arbitrary (A, B) — e.g. baselines-produced or merged factors; when A's
    columns are orthonormal it reduces to the ``sqrt(||B row_i||)`` scale."""
    eps = jnp.finfo(b.dtype).eps
    bn = jnp.maximum(jnp.linalg.norm(b, axis=1), eps)    # (r,)
    an = jnp.maximum(jnp.linalg.norm(a, axis=0), eps)    # (r,)
    rn = jnp.sqrt(bn / an)
    return a * rn[None, :], b / rn[:, None]


# ---------------------------------------------------------------------------
# Reference (Eckart–Young–Mirsky) building block
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("rank",))
def eym_truncate(a: jax.Array, rank: int) -> jax.Array:
    """Best rank-r approximation of ``a`` in Frobenius norm (Theorem 3)."""
    u, s, vt = jnp.linalg.svd(a, full_matrices=False)
    return (u[:, :rank] * s[:rank][None, :]) @ vt[:rank, :]


def weighted_error(w: jax.Array, w_approx: jax.Array, x: jax.Array) -> jax.Array:
    """||(W − W')X||_F — the objective of problem (3)."""
    return jnp.linalg.norm((w - w_approx) @ x)
