"""Theoretical quantities from the paper: gaps, bounds, projector distances.

Used by tests (the bound must hold empirically) and by the compression driver
(the Thm. 1 estimate informs μ selection sensitivity, §5 of the paper).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def singular_gap(m: jax.Array, rank: int) -> jax.Array:
    """σ_r(M) − σ_{r+1}(M)."""
    s = jnp.linalg.svd(m, compute_uv=False)
    return s[rank - 1] - s[rank]


def thm1_bound(w: jax.Array, x: jax.Array, rank: int, mu: float) -> jax.Array:
    """Theorem 1: ||W₀ − W_μ||_F ≤ 2‖W‖₂²‖W‖_F / (σ_r²−σ_{r+1}²)(WX) · μ.

    Holds with NO full-rank assumption on X (the degenerate/limited-data case).
    """
    s = jnp.linalg.svd(w @ x, compute_uv=False)
    gap2 = s[rank - 1] ** 2 - s[rank] ** 2
    w2 = jnp.linalg.norm(w, ord=2)
    return 2.0 * w2 ** 2 * jnp.linalg.norm(w) / gap2 * mu


def thm5_bound(w: jax.Array, x: jax.Array, rank: int, mu: float) -> jax.Array:
    """Theorem 5 (full-row-rank X): ‖W‖₂‖W‖_F /(σ_r−σ_{r+1})(WX) · μ/σ_n(X)."""
    s_wx = jnp.linalg.svd(w @ x, compute_uv=False)
    gap = s_wx[rank - 1] - s_wx[rank]
    sx = jnp.linalg.svd(x, compute_uv=False)
    return jnp.linalg.norm(w, ord=2) * jnp.linalg.norm(w) / gap * mu / sx[-1]


def projector_distance(u_a: jax.Array, u_b: jax.Array) -> jax.Array:
    """‖U_a U_aᵀ − U_b U_bᵀ‖₂ (Davis–Kahan–Wedin quantity, Thm. 4)."""
    p = u_a @ u_a.T - u_b @ u_b.T
    return jnp.linalg.norm(p, ord=2)


def relative_weighted_error(w: jax.Array, w_approx: jax.Array, x: jax.Array
                            ) -> jax.Array:
    """||(W−W')X||_F / ||WX||_F — Figure 1's y-axis."""
    return jnp.linalg.norm((w - w_approx) @ x) / jnp.linalg.norm(w @ x)


def optimal_weighted_error(w: jax.Array, x: jax.Array, rank: int) -> jax.Array:
    """The attainable minimum of ||(W−W')X||_F = sqrt(Σ_{i>r} σ_i²(WX))."""
    s = jnp.linalg.svd(w @ x, compute_uv=False)
    return jnp.sqrt(jnp.sum(s[rank:] ** 2))
