"""TSQR: Tall-Skinny QR for calibration matrices that never fit in memory.

The paper (§4.2) preprocesses the activation matrix ``X ∈ R^{n×k}`` (k = tokens,
can be millions) by a QR decomposition of ``Xᵀ``; only the ``R`` factor (n×n)
is needed downstream (Prop. 2). For large k we use the TSQR scheme of
Demmel et al. [11]:

  * ``tsqr_sequential`` — streaming: fold chunks into a running R (the paper's
    ``[R; X_iᵀ] → QR`` recurrence). O(n²) state, one pass over the data.
  * ``tsqr_tree`` — binary reduction tree over chunks (the paper's multi-GPU
    diagram).
  * ``distributed_tsqr_r`` — the TPU-native adaptation: a butterfly
    (XOR-pairing) reduction over a mesh axis inside ``shard_map``, built on
    ``lax.ppermute``. After log2(axis) rounds every device holds the SAME
    full R — an "all-reduce" in QR-land. This is the paper's tree mapped
    onto ICI collectives.

All functions return R with a sign convention (non-negative diagonal) so that
R is unique and comparable across strategies in tests.
"""
from __future__ import annotations

import math
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp


def _fix_sign(r: jax.Array) -> jax.Array:
    """Flip row signs so diag(R) >= 0 (makes R unique for full-rank input)."""
    d = jnp.diagonal(r)
    s = jnp.where(d < 0, -1.0, 1.0).astype(r.dtype)
    return r * s[:, None]


def qr_r(xt: jax.Array, fix_sign: bool = True) -> jax.Array:
    """R factor of the (reduced) QR of ``xt`` (rows = tokens, cols = n)."""
    r = jnp.linalg.qr(xt, mode="r")
    return _fix_sign(r) if fix_sign else r


def stack_qr(r_top: jax.Array, r_bot: jax.Array) -> jax.Array:
    """R factor of qr([R_top; R_bot]) — the TSQR combine step."""
    return qr_r(jnp.concatenate([r_top, r_bot], axis=0))


def tsqr_sequential(chunks: Iterable[jax.Array]) -> jax.Array:
    """Streaming TSQR: fold token-chunks (each (k_i, n) rows of Xᵀ)."""
    r: Optional[jax.Array] = None
    for c in chunks:
        if c.ndim != 2:
            raise ValueError(f"chunk must be 2-D (tokens, features), got {c.shape}")
        r = qr_r(c) if r is None else stack_qr(r, c)
    if r is None:
        raise ValueError("tsqr_sequential: no chunks")
    return r


def tsqr_tree(chunks: Sequence[jax.Array]) -> jax.Array:
    """Binary-tree TSQR (paper Fig. in §4.2): pairwise combine until one R."""
    rs = [qr_r(c) for c in chunks]
    while len(rs) > 1:
        nxt = []
        for i in range(0, len(rs) - 1, 2):
            nxt.append(stack_qr(rs[i], rs[i + 1]))
        if len(rs) % 2 == 1:
            nxt.append(rs[-1])
        rs = nxt
    return rs[0]


class RStreamer:
    """Stateful streaming R accumulator used by the calibration pipeline.

    Never materializes X: ``update`` consumes a (tokens, n) activation chunk,
    ``finish`` returns the final R (optionally μ-augmented, Prop. 3).
    """

    def __init__(self, n: int, dtype=jnp.float32):
        self.n = n
        self.dtype = dtype
        self._r: Optional[jax.Array] = None
        self.tokens_seen = 0
        self._update = jax.jit(stack_qr)
        self._first = jax.jit(qr_r)

    def update(self, chunk: jax.Array) -> None:
        chunk = chunk.reshape(-1, self.n).astype(self.dtype)
        self.tokens_seen += int(chunk.shape[0])
        self._r = self._first(chunk) if self._r is None else self._update(self._r, chunk)

    @property
    def r(self) -> jax.Array:
        if self._r is None:
            raise ValueError("RStreamer: no data seen")
        return self._r

    def finish(self, mu: float = 0.0) -> jax.Array:
        r = self.r
        if mu > 0.0:
            r = augment_r_with_mu(r, mu)
        return square_r(r)


def square_r(r: jax.Array) -> jax.Array:
    """Pad/keep R to a square (n, n) upper-triangular matrix."""
    k, n = r.shape
    if k == n:
        return r
    if k > n:  # cannot happen for reduced QR, but be safe
        return qr_r(r)
    return jnp.zeros((n, n), r.dtype).at[:k, :].set(r)


def augment_r_with_mu(r: jax.Array, mu: float) -> jax.Array:
    """R of the μ-augmented matrix X̃ = [X  √μ·I] (Prop. 3): qr([R; √μ I])."""
    n = r.shape[-1]
    eye = jnp.sqrt(jnp.asarray(mu, r.dtype)) * jnp.eye(n, dtype=r.dtype)
    return stack_qr(square_r(r), eye)


# ---------------------------------------------------------------------------
# Distributed TSQR over a mesh axis (shard_map body)
# ---------------------------------------------------------------------------

def distributed_tsqr_r(xt_local: jax.Array, axis_name: str) -> jax.Array:
    """Butterfly TSQR over mesh axis ``axis_name`` (call inside shard_map).

    xt_local: (k_local, n) local rows of Xᵀ. Returns the full R (replicated:
    every device along the axis computes the identical matrix).
    """
    size = jax.lax.axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    r = qr_r(xt_local)
    r = square_r(r)  # keep (n, n) so every round has a static shape
    rounds = int(math.log2(size))
    if 2 ** rounds != size:
        raise ValueError(f"axis size {size} must be a power of two for butterfly TSQR")
    for s in range(rounds):
        shift = 1 << s
        perm = [(i, i ^ shift) for i in range(size)]
        other = jax.lax.ppermute(r, axis_name, perm)
        partner = me ^ shift
        # Deterministic stacking order (lower device id on top) so both sides
        # of the pair compute the *same* R and the result stays replicated.
        top = jnp.where(me < partner, 0, 1)
        stacked = jnp.where(top == 0,
                            jnp.concatenate([r, other], axis=0),
                            jnp.concatenate([other, r], axis=0))
        r = qr_r(stacked)
    return r


def gram_chunked(chunks: Iterable[jax.Array]) -> jax.Array:
    """Baseline Gram accumulation  XXᵀ = Σ XᵢXᵢᵀ  (the numerically risky path
    the paper compares against; kept for the SVD-LLM baselines)."""
    g: Optional[jax.Array] = None
    for c in chunks:  # c: (tokens, n) rows of Xᵀ  -> contributes cᵀc
        contrib = c.T @ c
        g = contrib if g is None else g + contrib
    if g is None:
        raise ValueError("gram_chunked: no chunks")
    return g
