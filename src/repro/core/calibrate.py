"""Calibration: stream per-layer activation statistics into R factors.

The paper's memory story (§4.2): the calibration matrix X (n × tokens) can be
tens of GB, so we never materialize it. Each target linear layer owns an
``RStreamer`` — every captured activation chunk folds into a running n×n R
via TSQR ([R; chunkᵀ] → QR). The Gram accumulator (for the SVD-LLM baselines)
streams the same way via the Pallas ``gram_accum`` kernel.

On a mesh, the per-shard R factors combine with the butterfly
``distributed_tsqr_r`` (see core/tsqr.py) — calibration activations are
born sharded over the data axis and the tree never gathers them.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.core.tsqr import RStreamer, square_r
from repro.kernels import ops as kops
from repro.models.linear import CaptureDict
from repro.obs import trace


class Calibrator:
    """Capture sink + R accumulator. Use via ``model.capture_forward``."""

    def __init__(self, *, collect_gram: bool = False, dtype=jnp.float32,
                 max_tokens_per_record: int = 8192):
        self.streams: Dict[str, RStreamer] = {}
        self.grams: Dict[str, jax.Array] = {}
        self.collect_gram = collect_gram
        self.dtype = dtype
        self.max_tokens = max_tokens_per_record

    # ------------------------------------------------------------ capture
    def wrap(self, block_params, path: str):
        """Recursively wrap every linear-layer dict {'w': ...} — and MoE
        expert banks ('w_gate' dicts, captured per-expert) — for capture."""
        def walk(node, p):
            if isinstance(node, dict):
                if "w" in node and getattr(node["w"], "ndim", 0) == 2:
                    cd = CaptureDict(node)
                    cd.path = p
                    cd.calib = self
                    return cd
                inner = {k: walk(v, f"{p}/{k}") for k, v in node.items()}
                if "w_gate" in node:       # MoE layer: per-expert capture
                    cd = CaptureDict(inner)
                    cd.path = p
                    cd.calib = self
                    return cd
                return inner
            if isinstance(node, list):
                return [walk(v, f"{p}/{i}") for i, v in enumerate(node)]
            return node
        return walk(block_params, path)

    def record(self, path: str, x: jax.Array):
        n = x.shape[-1]
        flat = jnp.asarray(x, self.dtype).reshape(-1, n)
        with trace.span("calib.record", path=path, tokens=flat.shape[0]):
            if path not in self.streams:
                self.streams[path] = RStreamer(n, self.dtype)
            # fold in manageable chunks (bounds the QR stack size)
            for i in range(0, flat.shape[0], self.max_tokens):
                self.streams[path].update(flat[i:i + self.max_tokens])
            if self.collect_gram:
                g = kops.gram_accum(flat)
                self.grams[path] = g if path not in self.grams \
                    else self.grams[path] + g

    def reset(self) -> None:
        """Drop every accumulated stream and Gram, keeping the capture
        wiring intact — a rolling traffic window (serve/recalibrate.py)
        starts its next window on the same instance."""
        self.streams.clear()
        self.grams.clear()

    # ------------------------------------------------------------ results
    def r_factors(self) -> Dict[str, jax.Array]:
        return {p: square_r(s.r) for p, s in self.streams.items()}

    def tokens_seen(self) -> Dict[str, int]:
        return {p: s.tokens_seen for p, s in self.streams.items()}


def calibrate_model(model, params, batches: Iterable[dict], *,
                    collect_gram: bool = False) -> Calibrator:
    """Run capture over calibration batches; returns the filled Calibrator."""
    cal = Calibrator(collect_gram=collect_gram)
    for batch in batches:
        model.capture_forward(params, batch, cal)
    return cal
