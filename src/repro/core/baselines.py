"""Baselines the paper compares against (Appendix B + §2).

All are implemented *faithfully*, including their numerically fragile steps
(explicit Gram matrices, Cholesky of possibly-singular XXᵀ, inversion of
small singular values) — reproducing those failure modes is part of the
paper's Figure 1 / Example G.1 story.

  * ``svd_llm``      — Algorithm 3 [Wang et al. '25]: Cholesky of XXᵀ.
  * ``svd_llm_v2``   — Algorithm 4 [Wang et al. '25]: SVD of XXᵀ, S^{-1/2}.
  * ``asvd``         — activation-aware scaling [Yuan et al.]: diagonal S from
                       mean |activation| per channel (suboptimal but robust).
  * ``plain_svd``    — context-free Eckart–Young–Mirsky on W.
  * ``corda``        — CorDA [Yang et al. '24]: α=2 Gram-squared weighting with
                       explicit inversion (Remark 1's fragile form).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _svd_trunc(m: jax.Array, rank: int):
    u, s, vt = jnp.linalg.svd(m, full_matrices=False)
    return u[:, :rank], s[:rank], vt[:rank, :]


@partial(jax.jit, static_argnames=("rank",))
def svd_llm(w: jax.Array, gram: jax.Array, rank: int) -> Tuple[jax.Array, jax.Array]:
    """SVD-LLM (Appendix B, Algorithm 3). gram = XXᵀ.

    S = chol(XXᵀ) (upper, i.e. XXᵀ = SᵀS ... the paper uses S with W S then
    B = Σ_r V_rᵀ S^{-1}). On singular/indefinite Gram matrices Cholesky
    produces NaNs — faithfully kept.
    """
    # jnp.linalg.cholesky returns lower L with L Lᵀ = G; the algorithm's upper
    # triangular S is Lᵀ.
    s_factor = jnp.linalg.cholesky(gram).T
    u, s, vt = _svd_trunc(w @ s_factor.T, rank)  # W·Sᵀ: (m,n)  [SᵀS = G]
    a = u
    # B = Σ_r V_rᵀ S^{-T}: solve instead of explicit inverse (best practice,
    # still Gram/Cholesky-based as in the original method).
    b = jax.scipy.linalg.solve_triangular(s_factor, (s[:, None] * vt).T,
                                          lower=False, trans="T").T
    return a, b


@partial(jax.jit, static_argnames=("rank",))
def svd_llm_v2(w: jax.Array, gram: jax.Array, rank: int) -> Tuple[jax.Array, jax.Array]:
    """SVD-LLM v2 (Appendix B, Algorithm 4): eigendecompose XXᵀ, use S^{±1/2}."""
    us, sv, _ = jnp.linalg.svd(gram)             # G = Us diag(sv) Usᵀ
    m = w @ (us * jnp.sqrt(sv)[None, :])         # W Us S^{1/2}
    u, s, vt = _svd_trunc(m, rank)
    inv_sqrt = jnp.where(sv > 0, 1.0 / jnp.sqrt(sv), 0.0)  # blows up when tiny
    b = (s[:, None] * vt) @ (us * inv_sqrt[None, :]).T
    return u, b


@partial(jax.jit, static_argnames=("rank", "alpha"))
def asvd(w: jax.Array, x: jax.Array, rank: int, alpha: float = 0.5
         ) -> Tuple[jax.Array, jax.Array]:
    """ASVD: W ≈ (W S) S^{-1} with diagonal S_ii = (mean_k |X_ik|)^alpha."""
    act = jnp.mean(jnp.abs(x), axis=1)           # (n,)
    scale = jnp.maximum(act, 1e-6) ** alpha
    u, s, vt = _svd_trunc(w * scale[None, :], rank)
    b = (s[:, None] * vt) / scale[None, :]
    return u, b


@partial(jax.jit, static_argnames=("rank",))
def plain_svd(w: jax.Array, rank: int) -> Tuple[jax.Array, jax.Array]:
    """Context-free EYM truncation of W itself."""
    u, s, vt = _svd_trunc(w, rank)
    return u, s[:, None] * vt


@partial(jax.jit, static_argnames=("rank",))
def corda(w: jax.Array, x: jax.Array, rank: int) -> Tuple[jax.Array, jax.Array]:
    """CorDA (Remark 1): W' = U_r Σ_r V_rᵀ (XXᵀ)^{-1} from SVD of W·XXᵀ.

    The explicit Gram inverse is the fragile step COALA α=2 removes.
    """
    gram = x @ x.T
    u, s, vt = _svd_trunc(w @ gram, rank)
    b = jnp.linalg.solve(gram.T, (s[:, None] * vt).T).T
    return u, b
