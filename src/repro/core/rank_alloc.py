"""Adaptive per-layer rank allocation (beyond-paper extension).

The paper compresses every layer at the same ratio (Tables 2–3 note "without
adaptive rank selection"). Given the per-layer R factors COALA already
computes, the optimal rank split under a global parameter budget has a
closed greedy solution: the exact weighted-error reduction of granting a
layer one more rank is σ_{r+1}²(W Rᵀ) (Eckart–Young on the weighted
problem), at a parameter cost of (d_in + d_out). Water-filling on the
gain/cost ratio is optimal because singular values are sorted, so marginal
gains are non-increasing.

Scan-stacked layers add a structural constraint: every rep of the same
layer position must get the SAME rank (the factored params restack into one
scanned tensor). Those reps form one allocation group: granting the group
+1 rank costs n_rep·(d_in+d_out) and gains Σ_rep σ_{r+1,rep}².
"""
from __future__ import annotations

import heapq
import re
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

_STACK_RE = re.compile(r"^(blocks|enc|dec)/\d+/")


def default_group(path: str) -> str:
    """'blocks/3/sub0/mixer/wq' -> 'blocks/*/sub0/mixer/wq'."""
    return _STACK_RE.sub(lambda m: f"{m.group(1)}/*/", path)


def adaptive_rank_map(params_weights: Dict[str, object], r_factors,
                      ratio: float, *, min_rank: int = 1,
                      group_fn: Optional[Callable[[str], str]] = None
                      ) -> Dict[str, int]:
    """Returns {path: rank} meeting budget = ratio × Σ dense params."""
    group_fn = group_fn or default_group
    groups: Dict[str, list] = {}
    for p in params_weights:
        groups.setdefault(group_fn(p), []).append(p)

    gains: Dict[str, object] = {}       # per-group Σ_rep σ² (sorted desc)
    dims: Dict[str, Tuple[int, int, int]] = {}
    total_dense = 0
    for g, paths in groups.items():
        sq = None
        for p in paths:
            w = params_weights[p]
            r = r_factors[p]
            m = w.T.astype(jnp.float32) @ r.T.astype(jnp.float32)
            s2 = jnp.linalg.svd(m, compute_uv=False) ** 2
            sq = s2 if sq is None else sq + s2
        d_in, d_out = params_weights[paths[0]].shape
        dims[g] = (d_in, d_out, len(paths))
        gains[g] = sq
        total_dense += d_in * d_out * len(paths)
    budget = int(ratio * total_dense)

    ranks: Dict[str, int] = {}
    heap = []
    spent = 0
    for g, sq in gains.items():
        d_in, d_out, n = dims[g]
        cost = (d_in + d_out) * n
        r0 = min(min_rank, len(sq))
        ranks[g] = r0
        spent += r0 * cost
        if r0 < min(len(sq), d_in, d_out):
            heapq.heappush(heap, (-float(sq[r0]) / cost, g, r0))
    while heap:
        _, g, r = heapq.heappop(heap)
        if ranks[g] != r:
            continue                     # stale entry
        d_in, d_out, n = dims[g]
        cost = (d_in + d_out) * n
        if spent + cost > budget:
            continue                     # try cheaper groups
        ranks[g] = r + 1
        spent += cost
        sq = gains[g]
        if r + 1 < min(len(sq), d_in, d_out):
            heapq.heappush(heap, (-float(sq[r + 1]) / cost, g, r + 1))

    return {p: ranks[group_fn(p)] for p in params_weights}
