"""Shared model building blocks: norms, RoPE/M-RoPE, softcap, parallel context."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Runtime parallelism context threaded through model apply fns.

    ``mesh is None`` -> single-device math everywhere (CPU tests).
    """
    mesh: Optional[object] = None                   # jax.sharding.Mesh
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    shard_map_moe: bool = False                     # expert-parallel MoE path
    dense_attn_max_seq: int = 2048                  # above this -> chunked attn
    attn_chunk_q: int = 2048
    attn_chunk_kv: int = 1024
    causal_pair_scan: bool = False                  # §Perf: skip masked kv blocks
    moe_capacity_factor: Optional[float] = None     # override cfg capacity
    use_pallas: bool = False                        # TPU flash-attention kernel
    mlstm_chunkwise: bool = False                   # chunkwise-parallel mLSTM
    paged_attn_impl: Optional[str] = None           # paged decode kernel: None/
                                                    # "auto" | "pallas" | "ref"

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]


CPU_CTX = ParallelCtx()


def constrain_act(x, ctx: "ParallelCtx"):
    """Pin activations to (batch-sharded, replicated-features) at block
    boundaries. Without this GSPMD drifts activations through partial
    feature shardings and pays reshard collectives every layer (measured:
    ~40% of gemma2 train link bytes — see EXPERIMENTS.md §Perf)."""
    if ctx.mesh is None:
        return x
    from jax.sharding import AxisType, NamedSharding, PartitionSpec
    # inside a partially-manual shard_map (e.g. the pod-manual gradient
    # compression region) the manual axes may not appear in constraints
    try:
        am = jax.sharding.get_abstract_mesh()
        manual = {n for n, t in zip(am.axis_names, am.axis_types)
                  if t == AxisType.Manual}
    except Exception:
        # pinned jax (no abstract-mesh API): its SPMD partitioner cannot
        # express full-mesh constraints inside a manual subgroup at all
        # (hlo_sharding_util CHECK) — drop the layout hint there entirely.
        # A nonempty axis env means we are under shard_map/pmap.
        try:
            if jax.core.nonempty_axis_env_DO_NOT_USE():
                return x
        except Exception:
            pass
        manual = set()
    axes = tuple(a for a in ctx.batch_axes if a not in manual)
    if not axes:
        return x
    b = x.shape[0] if hasattr(x, "shape") and x.ndim else 0
    n_shards = 1
    for a in axes:
        n_shards *= ctx.mesh.shape[a]
    if not b or b % n_shards:
        return x
    spec = PartitionSpec(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}        # stored as (1+scale) gemma-style


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def nonparametric_ln(x, eps: float = 1e-5):
    """OLMo-style LayerNorm without learnable scale/bias."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def make_norm(cfg):
    """Returns (init_fn() -> params, apply_fn(params, x))."""
    if cfg.nonparametric_norm:
        return (lambda: {}), (lambda p, x: nonparametric_ln(x, cfg.norm_eps))
    return (lambda: rmsnorm_init(cfg.d_model)), \
           (lambda p, x: rmsnorm(p, x, cfg.norm_eps))


def softcap(x, cap: float):
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, dtype=jnp.float32):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=dtype) / half))


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions: (..., T) int -> cos/sin (..., T, head_dim//2)."""
    inv = rope_freqs(head_dim, theta)
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, T, H, hd); cos/sin: (B, T, hd//2) or (T, hd//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:                                # (T, half)
        cos_ = cos[None, :, None, :]
        sin_ = sin[None, :, None, :]
    else:                                            # (B, T, half)
        cos_ = cos[:, :, None, :]
        sin_ = sin[:, :, None, :]
    cos_, sin_ = cos_.astype(x.dtype), sin_.astype(x.dtype)
    return jnp.concatenate([x1 * cos_ - x2 * sin_,
                            x2 * cos_ + x1 * sin_], axis=-1)


def mrope_cos_sin(position_ids, head_dim: int, theta: float,
                  sections: Tuple[int, int, int]):
    """Qwen2-VL M-RoPE. position_ids: (3, B, T) for (t, h, w) streams.

    ``sections`` split head_dim//2 frequency slots among the three streams.
    Returns cos/sin of shape (B, T, head_dim//2).
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(head_dim, theta)                # (half,)
    ang = position_ids[..., None].astype(jnp.float32) * inv  # (3, B, T, half)
    splits = []
    start = 0
    for i, sec in enumerate(sections):
        splits.append(ang[i, :, :, start:start + sec])
        start += sec
    ang_sel = jnp.concatenate(splits, axis=-1)       # (B, T, half)
    return jnp.cos(ang_sel), jnp.sin(ang_sel)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float = 1.0):
    std = scale / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


def split_key(key, n: int):
    return list(jax.random.split(key, n))


def chunked_scan(f, init, xs, chunk: int, *, time_axis: int = 0):
    """``lax.scan`` over time with chunk-boundary checkpointing.

    A naive scan saves every per-step carry for backward — O(T·state) memory,
    prohibitive for recurrent layers (mamba/mLSTM/sLSTM) at 4k tokens. This
    wrapper scans over T/chunk chunks, saving ONLY chunk-boundary carries and
    rematerializing the inner steps in backward: memory O(T/chunk · state),
    compute overhead ≤ 2x on the recurrence (not on the projections).

    xs: pytree with leading time axis T (divisible chunking handled by
    falling back to plain scan when T % chunk != 0).
    """
    leaves = jax.tree.leaves(xs)
    t = leaves[0].shape[time_axis]
    if chunk <= 0 or t % chunk or t <= chunk:
        return jax.lax.scan(f, init, xs)
    n = t // chunk

    def reshape(x):
        return x.reshape((n, chunk) + x.shape[1:])

    xs_c = jax.tree.map(reshape, xs)

    @jax.checkpoint
    def chunk_body(carry, xs_chunk):
        return jax.lax.scan(f, carry, xs_chunk)

    carry, ys = jax.lax.scan(chunk_body, init, xs_c)

    def unshape(y):
        return y.reshape((t,) + y.shape[2:])

    return carry, jax.tree.map(unshape, ys)
