"""FFN family: GLU MLPs and Mixture-of-Experts.

MoE uses token-choice top-k routing with per-expert capacity (drop policy),
in two execution modes with identical math:

  * local   — single device, vmap over all experts (CPU tests / no mesh)
  * sharded — expert-parallel ``shard_map`` over the ``model`` mesh axis:
              tokens stay put (replicated within a model row, as in Megatron
              TP), each device routes to its E/model local experts, partial
              outputs combine with the same ``psum`` dense TP already pays.
              No all-to-all, no token shuffling across the data axis.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ParallelCtx, act_fn, dense_init, split_key
from repro.models.linear import linear_apply


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, *, glu: bool = True, dtype=jnp.float32):
    ks = split_key(key, 3)
    p = {"up": {"w": dense_init(ks[0], d_model, d_ff, dtype)},
         "down": {"w": dense_init(ks[1], d_ff, d_model, dtype)}}
    if glu:
        p["gate"] = {"w": dense_init(ks[2], d_model, d_ff, dtype)}
    return p


def mlp_apply(params, x, act: str = "silu"):
    f = act_fn(act)
    if "gate" in params:
        h = f(linear_apply(params["gate"], x)) * linear_apply(params["up"], x)
    else:
        h = f(linear_apply(params["up"], x))
    return linear_apply(params["down"], h)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    m = cfg.moe
    ks = split_key(key, 5)
    e, f = m.num_experts, m.d_ff_expert
    std = 1.0 / math.sqrt(d)

    def expert_stack(k, din, dout):
        return (jax.random.normal(k, (e, din, dout), jnp.float32)
                * (1.0 / math.sqrt(din))).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),   # fp32 router
        "w_gate": expert_stack(ks[1], d, f),
        "w_up": expert_stack(ks[2], d, f),
        "w_down": expert_stack(ks[3], f, d),
    }
    if m.num_shared > 0:
        p["shared"] = mlp_init(ks[4], d, m.num_shared * f, glu=True, dtype=dtype)
    del std
    return p


def _capacity(n_tokens: int, cfg, ctx: ParallelCtx) -> int:
    m = cfg.moe
    cf = ctx.moe_capacity_factor or m.capacity_factor
    cap = max(m.min_capacity,
              int(math.ceil(m.top_k * n_tokens / m.num_experts * cf)))
    return min(cap, n_tokens)


def _route(x_flat, router_w, cfg):
    """Returns per-token expert weight matrix gw (N, E) and aux loss scalar."""
    m = cfg.moe
    logits = (x_flat.astype(jnp.float32) @ router_w)          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)              # (N, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    gw = jnp.zeros_like(probs)
    gw = jnp.take_along_axis(gw, top_i, axis=-1)  # dummy to keep shapes clear
    gw = jnp.zeros_like(probs).at[
        jnp.arange(probs.shape[0])[:, None], top_i].set(top_p)
    # switch-style load-balance aux
    frac = jnp.mean((gw > 0).astype(jnp.float32), axis=0)     # f_e
    imp = jnp.mean(probs, axis=0)                             # P_e
    aux = m.num_experts * jnp.sum(frac * imp)
    return gw, aux


def _expert_ffn(x_e, wg, wu, wd, act):
    f = act_fn(act)

    def mm(x, w):
        if isinstance(w, tuple):          # COALA-factored expert: (b_t, a_t)
            return (x @ w[0]) @ w[1]
        return x @ w

    h = f(mm(x_e, wg)) * mm(x_e, wu)
    return mm(h, wd)


def _moe_local_math(x_flat, params, cfg, capacity: int, act: str,
                    e_start: int = 0, e_count: Optional[int] = None,
                    capture=None):
    """Route + dispatch + combine over experts [e_start, e_start+e_count)."""
    n, d = x_flat.shape
    gw, aux = _route(x_flat, params["router"].astype(jnp.float32), cfg)
    e_count = e_count if e_count is not None else cfg.moe.num_experts
    gw_loc = jax.lax.dynamic_slice_in_dim(gw, e_start, e_count, axis=1)  # (N, E_loc)
    w_sel, idx = jax.lax.top_k(gw_loc.T, capacity)            # (E_loc, C)
    x_e = x_flat[idx.reshape(-1)].reshape(e_count, capacity, d)

    def slice_w(w):
        return jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(
                a, e_start, e_count, 0).astype(x_flat.dtype), w)

    wg, wu, wd = (slice_w(params[k]) for k in ("w_gate", "w_up", "w_down"))
    if capture is not None:                 # eager calibration: per-expert X
        calib, path = capture
        f = act_fn(act)

        def mm_e(x, w, e):
            if isinstance(w, tuple):
                return (x @ w[0][e]) @ w[1][e]
            return x @ w[e]

        import numpy as _np
        for e in range(e_count):
            mask = _np.asarray(w_sel[e] > 0)
            x_used = _np.asarray(x_e[e])[mask]
            if x_used.shape[0]:
                x_used = jnp.asarray(x_used)
                calib.record(f"{path}/expert{e_start + e}/in", x_used)
                h_used = f(mm_e(x_used, wg, e)) * mm_e(x_used, wu, e)
                calib.record(f"{path}/expert{e_start + e}/hid", h_used)
    y_e = jax.vmap(_expert_ffn, in_axes=(0, 0, 0, 0, None))(
        x_e, wg, wu, wd, act)
    y_e = y_e * w_sel[..., None].astype(y_e.dtype)
    out = jnp.zeros((n, d), x_flat.dtype)
    out = out.at[idx.reshape(-1)].add(y_e.reshape(-1, d))
    return out, aux


def moe_apply(cfg, params, x, *, ctx: ParallelCtx) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, d) -> (y, aux_loss)."""
    b, t, d = x.shape
    m = cfg.moe

    if ctx.mesh is not None and ctx.shard_map_moe:
        e_loc = m.num_experts // ctx.model_size
        assert e_loc * ctx.model_size == m.num_experts, \
            f"experts {m.num_experts} must divide model axis {ctx.model_size}"
        n_shards = 1
        for a in ctx.batch_axes:
            n_shards *= ctx.mesh.shape[a]
        if b % n_shards:          # tiny-batch decode: replicate tokens instead
            n_shards = 1
            bspec = P(None, None, None)
        else:
            bspec = P(ctx.batch_axes, None, None)
        espec = P(ctx.model_axis, None, None)
        cap = _capacity(b * t // n_shards, cfg, ctx)

        def body(x_loc, router_w, wg, wu, wd):
            bl, tl, _ = x_loc.shape
            xf = x_loc.reshape(bl * tl, d)
            me = jax.lax.axis_index(ctx.model_axis)
            p_loc = {"router": router_w, "w_gate": wg, "w_up": wu, "w_down": wd}
            out, aux = _moe_local_math(xf, p_loc, cfg, cap, cfg.act,
                                       e_start=me * e_loc, e_count=e_loc)
            out = jax.lax.psum(out, ctx.model_axis)
            aux = jax.lax.pmean(aux, ctx.model_axis)
            return out.reshape(bl, tl, d), aux

        def etree(w):                      # dense array or factored tuple
            return jax.tree.map(lambda _: espec, w)

        y, aux = jax.shard_map(
            body, mesh=ctx.mesh,
            in_specs=(bspec, P(), etree(params["w_gate"]),
                      etree(params["w_up"]), etree(params["w_down"])),
            out_specs=(bspec, P()), check_vma=False,
        )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    else:
        cap = _capacity(b * t, cfg, ctx)
        capture = None
        from repro.models.linear import CaptureDict
        if isinstance(params, CaptureDict) and params.calib is not None:
            capture = (params.calib, params.path)
        y, aux = _moe_local_math(x.reshape(b * t, d), params, cfg, cap,
                                 cfg.act, capture=capture)
        y = y.reshape(b, t, d)

    if "shared" in params:
        y = y + mlp_apply(params["shared"], x, cfg.act)
    return y, aux * cfg.moe.aux_loss_weight
