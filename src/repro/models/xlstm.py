"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Faithful to the xLSTM paper's structure (arXiv:2405.04517): mLSTM is the
parallelizable matrix-memory cell with exponential gating and max-state
stabilization; sLSTM is the recurrent scalar-memory cell. Both expose
recurrent single-step updates, so decode state is O(1) in context length —
this is why the ``long_500k`` cell runs for xlstm-1.3b.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParallelCtx, chunked_scan, dense_init, split_key
from repro.models.linear import linear_apply


def _mlstm_dims(cfg):
    di = int(cfg.xlstm.proj_factor * cfg.d_model)
    h = cfg.n_heads
    return di, h, di // h


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di, h, hd = _mlstm_dims(cfg)
    ks = split_key(key, 8)
    return {
        "up": {"w": dense_init(ks[0], d, 2 * di, dtype)},
        "wq": {"w": dense_init(ks[1], di, di, dtype)},
        "wk": {"w": dense_init(ks[2], di, di, dtype)},
        "wv": {"w": dense_init(ks[3], di, di, dtype)},
        "w_i": dense_init(ks[4], di, h, jnp.float32),
        "w_f": dense_init(ks[5], di, h, jnp.float32),
        "f_bias": jnp.full((h,), 3.0, jnp.float32),     # forget-gate bias init
        "o_norm_scale": jnp.ones((di,), jnp.float32),
        "down": {"w": dense_init(ks[7], di, d, dtype)},
    }


def mlstm_empty_cache(cfg, batch: int, dtype=jnp.float32):
    _, h, hd = _mlstm_dims(cfg)
    return {"c": jnp.zeros((batch, h, hd, hd), dtype),
            "n": jnp.zeros((batch, h, hd), dtype),
            "m": jnp.full((batch, h), -1e30, dtype)}


def _mlstm_cell(c, n, m, q, k, v, log_i, log_f):
    """One recurrent step. q/k/v: (B,H,hd); log gates (B,H)."""
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)                       # (B,H)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s[..., None, None] * c + i_s[..., None, None] * \
        (k[..., :, None] * v[..., None, :])            # (B,H,hd_k,hd_v)
    n_new = f_s[..., None] * n + i_s[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)),
                        jnp.exp(-m_new))
    y = jnp.einsum("bhkv,bhk->bhv", c_new, q) / denom[..., None]
    return c_new, n_new, m_new, y


def _mlstm_chunkwise(q, k, v, log_i, log_f, state, chunk: int):
    """Chunkwise-parallel mLSTM (beyond-paper TPU adaptation).

    The sequential cell writes the (hd×hd) matrix memory every token —
    HBM-traffic-bound and MXU-hostile. This form processes chunks of L
    tokens: intra-chunk work is two (L×L)/(L×hd) matmuls (MXU-friendly),
    the matrix state is read/written once per chunk (HBM traffic ÷ L).
    Bit-compatible with the sequential recurrence's stabilization (same
    m_t = max(a_t + m₀, cummax_s(li_s − a_s) + a_t) telescoping).

    q,k,v: (B,T,H,hd) fp32 (pre-scaled); log gates (B,T,H). Returns
    (y (B,T,H,hd), final_state).
    """
    b, t, h, hd = q.shape
    n = t // chunk

    def per_chunk(carry, inp):
        c_st, n_st, m_st = carry               # (B,H,K,V) (B,H,K) (B,H)
        qc, kc, vc, lic, lfc = inp             # (B,L,H,·)
        a = jnp.cumsum(lfc, axis=1)            # (B,L,H) inclusive decay
        a_tot = a[:, -1]                       # (B,H)
        cmax = jax.lax.cummax(lic - a, axis=1)
        m_t = jnp.maximum(a + m_st[:, None, :], cmax + a)      # (B,L,H)
        scale_in = jnp.exp(a + m_st[:, None, :] - m_t)
        h_inter = jnp.einsum("blhk,bhkv->blhv", qc, c_st) * scale_in[..., None]
        qn_inter = jnp.einsum("blhk,bhk->blh", qc, n_st) * scale_in
        # intra-chunk: D_{t,s} = exp(li_s - a_s + a_t - m_t), s <= t
        logd = ((lic - a)[:, None, :, :] + a[:, :, None, :]
                - m_t[:, :, None, :])          # (B, Lt, Ls, H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        d = jnp.where(tri[None, :, :, None], jnp.exp(logd), 0.0)
        s_mat = jnp.einsum("bthk,bshk->btsh", qc, kc) * d
        h_intra = jnp.einsum("btsh,bshv->bthv", s_mat, vc)
        qn = qn_inter + jnp.sum(s_mat, axis=2)
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
        y = (h_inter + h_intra) / denom[..., None]
        # state to chunk end
        m_next = jnp.maximum(a_tot + m_st, cmax[:, -1] + a_tot)
        carry_scale = jnp.exp(a_tot + m_st - m_next)
        w_out = jnp.exp(lic - a + a_tot[:, None, :] - m_next[:, None, :])
        c_next = carry_scale[..., None, None] * c_st + jnp.einsum(
            "bshk,bshv->bhkv", kc * w_out[..., None], vc)
        n_next = carry_scale[..., None] * n_st + jnp.sum(
            kc * w_out[..., None], axis=1)
        return (c_next, n_next, m_next), y

    def resh(x_):
        return jnp.moveaxis(x_.reshape(b, n, chunk, *x_.shape[2:]), 1, 0)

    (c_f, n_f, m_f), ys = jax.lax.scan(
        per_chunk, state, tuple(resh(a) for a in (q, k, v, log_i, log_f)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, hd)
    return y, (c_f, n_f, m_f)


def mlstm_apply(cfg, params, x, *, ctx: ParallelCtx, cache=None, pos=None,
                **_) -> Tuple[jax.Array, Optional[dict]]:
    b, t, d = x.shape
    di, h, hd = _mlstm_dims(cfg)
    uz = linear_apply(params["up"], x)
    u, z = jnp.split(uz, 2, axis=-1)                   # (B,T,di)
    q = linear_apply(params["wq"], u).reshape(b, t, h, hd) / math.sqrt(hd)
    k = linear_apply(params["wk"], u).reshape(b, t, h, hd) / math.sqrt(hd)
    v = linear_apply(params["wv"], u).reshape(b, t, h, hd)
    log_i = (u.astype(jnp.float32) @ params["w_i"])     # (B,T,H)
    log_f = jax.nn.log_sigmoid(u.astype(jnp.float32) @ params["w_f"]
                               + params["f_bias"])

    if cache is not None:
        c0 = cache["c"].astype(jnp.float32)
        n0 = cache["n"].astype(jnp.float32)
        m0 = cache["m"].astype(jnp.float32)
    else:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)

    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))

    if ctx.mlstm_chunkwise and t > 1 and t % cfg.xlstm.chunk_size == 0:
        y4, (c_f, n_f, m_f) = _mlstm_chunkwise(
            qf, kf, vf, log_i, log_f, (c0, n0, m0),
            chunk=cfg.xlstm.chunk_size)
        y = y4.reshape(b, t, di).astype(x.dtype)
    else:
        def step(carry, inp):
            c, n, m = carry
            q_t, k_t, v_t, li_t, lf_t = inp
            c, n, m, y_t = _mlstm_cell(c, n, m, q_t, k_t, v_t, li_t, lf_t)
            return (c, n, m), y_t

        (c_f, n_f, m_f), ys = chunked_scan(
            step, (c0, n0, m0),
            tuple(jnp.moveaxis(a, 1, 0) for a in (qf, kf, vf, log_i, log_f)),
            chunk=cfg.xlstm.chunk_size)
        y = jnp.moveaxis(ys, 0, 1).reshape(b, t, di).astype(x.dtype)

    new_cache = None
    if cache is not None:
        new_cache = {"c": c_f.astype(cache["c"].dtype),
                     "n": n_f.astype(cache["n"].dtype),
                     "m": m_f.astype(cache["m"].dtype)}

    # group-norm-ish output scaling, gate, down-projection
    y = y * params["o_norm_scale"].astype(y.dtype)
    y = y * jax.nn.silu(z)
    return linear_apply(params["down"], y), new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    ks = split_key(key, 10)
    gates = {}
    for i, g in enumerate(("i", "f", "z", "o")):
        gates[f"w_{g}"] = {"w": dense_init(ks[i], d, d, dtype)}
        gates[f"r_{g}"] = (jax.random.normal(ks[4 + i], (h, hd, hd), jnp.float32)
                           / math.sqrt(hd)).astype(dtype)
    gates["f_bias"] = jnp.full((d,), 3.0, jnp.float32)
    ff = int(4 / 3 * d)
    gates["ff_up"] = {"w": dense_init(ks[8], d, 2 * ff, dtype)}
    gates["ff_down"] = {"w": dense_init(ks[9], ff, d, dtype)}
    return gates


def slstm_empty_cache(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), dtype), "n": jnp.zeros((batch, d), dtype),
            "h": jnp.zeros((batch, d), dtype),
            "m": jnp.full((batch, d), -1e30, dtype)}


def _slstm_scan(cfg, params, x, state):
    """x: (B,T,d). Recurrent h feeds back through per-head recurrent mats."""
    b, t, d = x.shape
    h_heads = cfg.n_heads
    hd = d // h_heads
    pre = {g: linear_apply(params[f"w_{g}"], x).astype(jnp.float32)
           for g in ("i", "f", "z", "o")}
    pre["f"] = pre["f"] + params["f_bias"]
    r = {g: params[f"r_{g}"].astype(jnp.float32) for g in ("i", "f", "z", "o")}

    def rec(h_prev, g):                                # (B,d) @ blockdiag R
        hh = h_prev.reshape(b, h_heads, hd)
        return jnp.einsum("bhk,hkv->bhv", hh, r[g]).reshape(b, d)

    def step(carry, inp):
        c, n, h_prev, m = carry
        pi, pf, pz, po = inp
        li = pi + rec(h_prev, "i")
        lf = jax.nn.log_sigmoid(pf + rec(h_prev, "f"))
        z = jnp.tanh(pz + rec(h_prev, "z"))
        o = jax.nn.sigmoid(po + rec(h_prev, "o"))
        m_new = jnp.maximum(lf + m, li)
        i_s = jnp.exp(li - m_new)
        f_s = jnp.exp(lf + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    state_f, hs = chunked_scan(
        step, state,
        tuple(jnp.moveaxis(pre[g], 1, 0) for g in ("i", "f", "z", "o")),
        chunk=cfg.xlstm.chunk_size)
    return jnp.moveaxis(hs, 0, 1), state_f             # (B,T,d)


def slstm_apply(cfg, params, x, *, ctx: ParallelCtx, cache=None, pos=None,
                **_) -> Tuple[jax.Array, Optional[dict]]:
    b, t, d = x.shape
    if cache is not None:
        state = tuple(cache[k].astype(jnp.float32) for k in ("c", "n", "h", "m"))
    else:
        state = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
                 jnp.zeros((b, d), jnp.float32), jnp.full((b, d), -1e30, jnp.float32))
    y, state_f = _slstm_scan(cfg, params, x, state)
    y = y.astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {k: v.astype(cache[k].dtype)
                     for k, v in zip(("c", "n", "h", "m"), state_f)}
    # gated feed-forward (proj factor 4/3, GLU)
    up = linear_apply(params["ff_up"], y)
    a, g = jnp.split(up, 2, axis=-1)
    y = linear_apply(params["ff_down"], jax.nn.gelu(a) * g)
    return y, new_cache
