"""Decoder-only LM assembly: scan over super-blocks, caches, chunked loss.

The repeating layer pattern of every architecture (dense, gemma2 local/global
pairs, deepseek first-dense-then-MoE, jamba 1:7 attn:mamba with interleaved
MoE, xlstm sLSTM/mLSTM mix) is expressed as a ``prefix`` of unrolled layers
plus a ``period`` scanned ``n_rep`` times over stacked params — one compiled
block body regardless of depth, which keeps HLO size and compile time flat.

Loss never materializes (B, T, vocab) logits: a scan over sequence chunks
computes partial cross-entropy against the (possibly vocab-sharded) LM head.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.common import (CPU_CTX, ParallelCtx, constrain_act, make_norm,
                                 mrope_cos_sin, rope_cos_sin, softcap,
                                 dense_init, split_key)
from repro.models.linear import linear_apply


def chunked_ce(h, targets, head_w, *, transform=None, chunk: int = 512):
    """Cross-entropy without materializing (B, T, vocab) logits.

    Scans over sequence chunks (padding + masking the tail so any T works);
    each chunk computes its logits against the (possibly vocab-sharded) head
    and reduces to scalars immediately.
    """
    b, t, d = h.shape
    ck = min(chunk, t)
    pad = (-t) % ck
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    mask = (jnp.arange(t + pad) < t).astype(jnp.float32)   # (T+pad,)
    nck = (t + pad) // ck

    def chunk_body(carry, xs):
        tot, cnt = carry
        h_c, y_c, m_c = xs                               # (B,ck,d) (B,ck) (ck,)
        logits = (h_c @ head_w.astype(h_c.dtype)).astype(jnp.float32)
        if transform is not None:
            logits = transform(logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum((lse - gold) * m_c[None, :])
        cnt = cnt + b * jnp.sum(m_c)
        return (tot, cnt), None

    h_r = h.reshape(b, nck, ck, d).swapaxes(0, 1)
    y_r = targets.reshape(b, nck, ck).swapaxes(0, 1)
    m_r = mask.reshape(nck, ck)
    (tot, cnt), _ = jax.lax.scan(
        chunk_body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (h_r, y_r, m_r))
    return tot / cnt


@dataclasses.dataclass(frozen=True)
class SubSpec:
    kind: str          # attn | mamba | mlstm | slstm
    is_moe: bool
    is_local: bool


def period_specs(cfg: ModelConfig):
    """(prefix_specs, period_specs, n_rep). Pattern must be periodic."""
    n = cfg.n_layers

    def spec(i):
        return SubSpec(cfg.layer_kind(i), cfg.layer_is_moe(i),
                       cfg.layer_is_local_attn(i))

    prefix = list(range(cfg.first_k_dense))
    rest = n - len(prefix)
    # period length: lcm of the pattern generators present
    p = 1
    if cfg.local_window > 0:
        p = max(p, 2)
    if cfg.attn_every:
        p = max(p, cfg.attn_every)
    if cfg.uses_moe and cfg.moe_every > 1:
        p = max(p, cfg.moe_every)
    if cfg.family == "ssm" and cfg.xlstm.slstm_every:
        p = max(p, cfg.xlstm.slstm_every)
    while rest % p:
        p += 1                      # fall back to a longer period that divides
    base = len(prefix)
    # verify periodicity
    for i in range(base, n):
        a, b = spec(i), spec(base + (i - base) % p)
        assert a == b, f"layer pattern not periodic: layer {i} {a} != {b}"
    return ([spec(i) for i in range(base)],
            [spec(base + j) for j in range(p)], rest // p)


# ---------------------------------------------------------------------------
# Per-sublayer init / apply
# ---------------------------------------------------------------------------

_MIXER_INIT = {
    "attn": lambda key, cfg, dt: (attn.mla_init(key, cfg, dt)
                                  if cfg.kv_lora_rank else attn.gqa_init(key, cfg, dt)),
    "mamba": ssm_lib.mamba_init,
    "mlstm": xlstm_lib.mlstm_init,
    "slstm": xlstm_lib.slstm_init,
}

_MIXER_APPLY = {
    "attn": lambda cfg, p, x, **kw: (attn.mla_apply(cfg, p, x, **kw)
                                     if cfg.kv_lora_rank
                                     else attn.gqa_apply(cfg, p, x, **kw)),
    "mamba": ssm_lib.mamba_apply,
    "mlstm": xlstm_lib.mlstm_apply,
    "slstm": xlstm_lib.slstm_apply,
}


def _has_ffn(cfg, spec: SubSpec) -> bool:
    return cfg.family != "ssm"      # xlstm blocks carry their own projections


def block_init(key, cfg: ModelConfig, spec: SubSpec, dtype=jnp.float32):
    norm_init, _ = make_norm(cfg)
    ks = split_key(key, 4)
    p: Dict[str, Any] = {"norm1": norm_init(),
                         "mixer": _MIXER_INIT[spec.kind](ks[0], cfg, dtype)}
    if _has_ffn(cfg, spec):
        p["norm2"] = norm_init()
        if spec.is_moe:
            p["ffn"] = ffn_lib.moe_init(ks[1], cfg, dtype)
        else:
            p["ffn"] = ffn_lib.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                        glu=cfg.family != "encdec", dtype=dtype)
    if cfg.post_block_norm:
        p["post1"] = norm_init()
        if _has_ffn(cfg, spec):
            p["post2"] = norm_init()
    return p


def block_apply(cfg, spec: SubSpec, params, x, *, ctx: ParallelCtx,
                cos_sin, cache=None, pos=None, paged_tables=None,
                lens=None):
    """Returns (x, aux, new_cache)."""
    _, norm = make_norm(cfg)
    res_scale = (cfg.scale_depth / math.sqrt(cfg.n_layers)
                 if cfg.scale_depth else 1.0)
    aux = jnp.zeros((), jnp.float32)

    mixer_kw = dict(ctx=ctx, cache=None if cache is None else cache.get("mixer"),
                    pos=pos)
    if spec.kind == "attn":
        mixer_kw.update(cos_sin=cos_sin, local=spec.is_local,
                        paged_tables=paged_tables, lens=lens)
    h, new_mixer_cache = _MIXER_APPLY[spec.kind](
        cfg, params["mixer"], norm(params["norm1"], x), **mixer_kw)
    if cfg.post_block_norm:
        h = norm(params["post1"], h)
    x = x + res_scale * h

    if _has_ffn(cfg, spec):
        h = norm(params["norm2"], x)
        if spec.is_moe:
            h, aux = ffn_lib.moe_apply(cfg, params["ffn"], h, ctx=ctx)
        else:
            h = ffn_lib.mlp_apply(params["ffn"], h, cfg.act)
        if cfg.post_block_norm:
            h = norm(params["post2"], h)
        x = x + res_scale * h

    new_cache = None
    if cache is not None:
        new_cache = {"mixer": new_mixer_cache if new_mixer_cache is not None
                     else cache.get("mixer")}
    return x, aux, new_cache


def _block_cache(cfg, spec: SubSpec, batch: int, max_len: int, dtype):
    if spec.kind == "attn":
        if cfg.kv_lora_rank:
            return {"mixer": attn.mla_empty_cache(cfg, batch, max_len, dtype)}
        return {"mixer": attn.gqa_empty_cache(cfg, batch, max_len, dtype)}
    if spec.kind == "mamba":
        return {"mixer": ssm_lib.mamba_empty_cache(cfg, batch)}
    if spec.kind == "mlstm":
        return {"mixer": xlstm_lib.mlstm_empty_cache(cfg, batch)}
    if spec.kind == "slstm":
        return {"mixer": xlstm_lib.slstm_empty_cache(cfg, batch)}
    raise ValueError(spec.kind)


# ---------------------------------------------------------------------------
# The LM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelConfig

    # ---------------- params ------------------------------------------------
    def init(self, key, dtype=jnp.float32):
        cfg = self.cfg
        prefix, period, n_rep = period_specs(cfg)
        ks = split_key(key, 4 + len(prefix) + len(period) * n_rep)
        params: Dict[str, Any] = {
            "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dtype),
        }
        norm_init, _ = make_norm(cfg)
        params["final_norm"] = norm_init()
        if not cfg.tie_embeddings:
            params["lm_head"] = {"w": dense_init(ks[1], cfg.d_model,
                                                 cfg.vocab_size, dtype)}
        if cfg.family == "vlm" and cfg.n_vision_tokens:
            params["vision_proj"] = {"w": dense_init(ks[2], cfg.d_model,
                                                     cfg.d_model, dtype)}
        ki = 4
        params["prefix"] = []
        for spec in prefix:
            params["prefix"].append(block_init(ks[ki], cfg, spec, dtype))
            ki += 1
        reps = []
        for rep in range(n_rep):
            blk = {}
            for j, spec in enumerate(period):
                blk[f"sub{j}"] = block_init(ks[ki], cfg, spec, dtype)
                ki += 1
            reps.append(blk)
        params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
        return params

    # ---------------- caches -----------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        prefix, period, n_rep = period_specs(cfg)
        cache = {"prefix": [_block_cache(cfg, s, batch, max_len, dtype)
                            for s in prefix]}
        one = {f"sub{j}": _block_cache(cfg, s, batch, max_len, dtype)
               for j, s in enumerate(period)}
        cache["blocks"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_rep,) + a.shape), one)
        return cache

    # ---------------- embedding & positions ---------------------------------
    def _embed(self, params, tokens, extra_embeds=None):
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.scale_emb != 1.0:
            x = x * cfg.scale_emb
        if extra_embeds is not None:                    # vlm: vision prefix
            v = extra_embeds.astype(x.dtype)
            if "vision_proj" in params:
                v = linear_apply(params["vision_proj"], v)
            x = jnp.concatenate([v, x], axis=1)
        return x

    def _cos_sin(self, batch: int, t: int, offset=0):
        cfg = self.cfg
        if cfg.family == "encdec":
            return None
        off = jnp.asarray(offset)
        # offset (B,) -> per-request positions (B, t); scalar -> shared (t,)
        pos = (off[:, None] if off.ndim else off) + jnp.arange(t)
        if cfg.mrope_sections != (0, 0, 0):
            pids = jnp.broadcast_to(pos, (3, batch, t))
            return mrope_cos_sin(pids, cfg.head_dim, cfg.rope_theta,
                                 cfg.mrope_sections)
        hd = cfg.qk_rope_dim if cfg.kv_lora_rank else cfg.head_dim
        return rope_cos_sin(pos, hd, cfg.rope_theta)

    # ---------------- backbone ----------------------------------------------
    def _backbone(self, params, x, *, ctx: ParallelCtx, cache=None, pos=None,
                  paged_tables=None, lens=None, remat: str = "none",
                  capture=None):
        cfg = self.cfg
        prefix, period, n_rep = period_specs(cfg)
        b, t = x.shape[0], x.shape[1]
        cos_sin = self._cos_sin(b, t, 0 if pos is None else pos)
        aux_total = jnp.zeros((), jnp.float32)

        new_prefix_caches = []
        for i, spec in enumerate(prefix):
            c = cache["prefix"][i] if cache is not None else None
            lp = params["prefix"][i]
            if capture is not None:
                lp = capture.wrap(lp, f"prefix/{i}")
            x, aux, nc = block_apply(cfg, spec, lp, x,
                                     ctx=ctx, cos_sin=cos_sin, cache=c, pos=pos,
                                     paged_tables=paged_tables, lens=lens)
            aux_total += aux
            new_prefix_caches.append(nc)

        if capture is not None:
            # unrolled-eager path: python loop so activations are concrete
            assert cache is None, "capture runs on the forward path only"
            for r in range(n_rep):
                blk = jax.tree.map(lambda a: a[r], params["blocks"])
                for j, spec in enumerate(period):
                    lp = capture.wrap(blk[f"sub{j}"], f"blocks/{r}/sub{j}")
                    x, a, _ = block_apply(cfg, spec, lp, x, ctx=ctx,
                                          cos_sin=cos_sin)
                    aux_total = aux_total + a
            _, norm = make_norm(cfg)
            return norm(params["final_norm"], x), aux_total, None

        def body(carry, xs):
            x, aux = carry
            blk, blk_cache = xs
            new_caches = {}
            x = constrain_act(x, ctx)      # pin layout at block boundaries
            for j, spec in enumerate(period):
                c = blk_cache[f"sub{j}"] if blk_cache is not None else None
                x, a, nc = block_apply(cfg, spec, blk[f"sub{j}"], x, ctx=ctx,
                                       cos_sin=cos_sin, cache=c, pos=pos,
                                       paged_tables=paged_tables, lens=lens)
                aux = aux + a
                new_caches[f"sub{j}"] = nc
            x = constrain_act(x, ctx)
            return (x, aux), (new_caches if blk_cache is not None else 0)

        if remat == "full":
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        elif remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

        blk_caches = cache["blocks"] if cache is not None else None
        (x, aux_total2), scanned_caches = jax.lax.scan(
            body, (x, aux_total),
            (params["blocks"], blk_caches) if blk_caches is not None
            else (params["blocks"], None))

        _, norm = make_norm(cfg)
        x = norm(params["final_norm"], x)
        new_cache = None
        if cache is not None:
            new_cache = {"prefix": new_prefix_caches, "blocks": scanned_caches}
        return x, aux_total2, new_cache

    # ---------------- heads --------------------------------------------------
    def _head_w(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]["w"]

    def _logits(self, params, h):
        cfg = self.cfg
        w = self._head_w(params).astype(h.dtype)
        logits = (h @ w).astype(jnp.float32)
        if cfg.dim_model_base:
            logits = logits / (cfg.d_model / cfg.dim_model_base)
        logits = softcap(logits, cfg.final_logit_softcap)
        return logits

    # ---------------- public: train loss ------------------------------------
    def loss(self, params, batch: Dict[str, jax.Array], *,
             ctx: ParallelCtx = CPU_CTX, remat: str = "none",
             loss_chunk: int = 512,
             compute_dtype=jnp.bfloat16) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """batch: tokens (B,T) int32, plus optional vision_embeds.

        Next-token CE; for vlm the vision prefix positions are excluded.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens, batch.get("vision_embeds"))
        x = x.astype(compute_dtype)
        h, aux, _ = self._backbone(params, x, ctx=ctx, remat=remat)

        n_vis = cfg.n_vision_tokens if cfg.family == "vlm" else 0
        h_text = h[:, n_vis:]
        targets = tokens[:, 1:]                          # predict next token
        h_in = h_text[:, :-1]

        def transform(logits):
            if cfg.dim_model_base:
                logits = logits / (cfg.d_model / cfg.dim_model_base)
            return softcap(logits, cfg.final_logit_softcap)

        ce = chunked_ce(h_in, targets, self._head_w(params),
                        transform=transform, chunk=loss_chunk)
        return ce + aux, {"ce": ce, "aux": aux}

    # ---------------- public: calibration ------------------------------------
    def capture_forward(self, params, batch, calibrator, *,
                        ctx: ParallelCtx = CPU_CTX, compute_dtype=jnp.float32):
        """Unrolled-eager forward that streams every target linear's input
        activations into the calibrator (per-layer R factors, never X)."""
        x = self._embed(params, batch["tokens"], batch.get("vision_embeds"))
        x = x.astype(compute_dtype)
        h, _, _ = self._backbone(params, x, ctx=ctx, capture=calibrator)
        return h

    def capture_prefill(self, params, tokens, calibrator, *,
                        ctx: ParallelCtx = CPU_CTX,
                        compute_dtype=jnp.float32):
        """Capture hook on the serving prefill path: one request's token
        stream ``tokens`` (T,) runs the unrolled-eager forward, streaming
        every target linear's input activations into ``calibrator``.

        Causality makes this the exact replay of what serving computed:
        the activation at position p depends only on tokens <= p, so a
        calibrator that records position range [start, T) here sees the
        same rows a live prefill/decode over those positions produced
        (serve/recalibrate.py slices via its ``record`` override)."""
        batch = {"tokens": jnp.asarray(tokens, jnp.int32).reshape(1, -1)}
        return self.capture_forward(params, batch, calibrator, ctx=ctx,
                                    compute_dtype=compute_dtype)

    # ---------------- public: serving ---------------------------------------
    def prefill(self, params, tokens, cache, *, ctx: ParallelCtx = CPU_CTX,
                vision_embeds=None, compute_dtype=jnp.bfloat16):
        x = self._embed(params, tokens, vision_embeds).astype(compute_dtype)
        h, _, cache = self._backbone(params, x, ctx=ctx, cache=cache, pos=None)
        return self._logits(params, h[:, -1:]), cache

    def prefill_chunk(self, params, tokens, cache, pos, lens, *,
                      ctx: ParallelCtx = CPU_CTX, compute_dtype=jnp.bfloat16,
                      block_tables=None):
        """Prefill a batch of suffix chunks at per-request cache offsets.

        tokens: (B, L) int32 — each row is a request's un-cached prompt
        suffix, right-padded to the shared length bucket ``L``; pos: (B,)
        int32 start offsets (the length of the row's cached prefix); lens:
        (B,) int32 valid token counts per row. Rides the same vector-``pos``
        attention path as ``decode_step`` (row-wise cache writes at
        ``pos[i] + j``, per-row causal masks over the whole cache), so a row
        attends to its cached prefix KV without recomputing it. Returns the
        logits at each row's last *valid* token, (B, vocab).

        With ``block_tables`` (B, nb) the cache is the paged view from
        ``BlockPool.paged_cache`` — attention layers scatter the suffix K/V
        into their pages and attend through the table indirection
        (``kernels/chunked_prefill.py``) instead of a gathered contiguous
        cache.

        Padded tail tokens (``j >= lens[i]``) write garbage K/V past the
        row's real length; the causal mask hides those positions until a
        later decode overwrites them, and ``BlockPool.scatter_suffix`` (the
        gather path) never writes blocks past the suffix back to the pool —
        the paged path's garbage lands in the row's own last partial page
        or the trash page.
        """
        x = self._embed(params, tokens).astype(compute_dtype)
        h, _, cache = self._backbone(params, x, ctx=ctx, cache=cache, pos=pos,
                                     paged_tables=block_tables, lens=lens)
        idx = jnp.maximum(lens - 1, 0)
        h_last = jnp.take_along_axis(
            h, idx[:, None, None].astype(jnp.int32), axis=1)
        return self._logits(params, h_last)[:, 0], cache

    def verify_chunk(self, params, tokens, cache, pos, lens, *,
                     ctx: ParallelCtx = CPU_CTX, compute_dtype=jnp.bfloat16,
                     block_tables=None):
        """Speculative-decoding verifier: ``prefill_chunk`` returning the
        logits at *every* position, (B, L, vocab), not just the last valid
        one.

        tokens: (B, L) int32 — row i is ``[last_committed, d_1..d_{L-1}]``,
        the request's last emitted token followed by its draft proposals;
        pos: (B,) start offsets (the request's ``cache_len``); lens: (B,)
        valid counts. Rides the identical row-offset attention path as
        ``prefill_chunk`` (the PR-4 L-token paged write path), so one call
        scores all L positions against the cache: ``logits[:, i]`` is the
        target's next-token distribution after consuming position
        ``pos + i``, which accept/reject compares with proposal ``d_{i+1}``.
        K/V for rejected tail tokens lands past the accepted length and is
        overwritten by the next round before any causal mask can expose it.
        """
        x = self._embed(params, tokens).astype(compute_dtype)
        h, _, cache = self._backbone(params, x, ctx=ctx, cache=cache, pos=pos,
                                     paged_tables=block_tables, lens=lens)
        return self._logits(params, h), cache

    def decode_step(self, params, tokens, cache, pos, *,
                    ctx: ParallelCtx = CPU_CTX, compute_dtype=jnp.bfloat16,
                    block_tables=None):
        """tokens: (B, 1) int32; pos: scalar int32 or (B,) int32 vector of
        per-request positions being written (continuous batching).

        With ``block_tables`` (B, nb) the cache is the paged view from
        ``BlockPool.paged_cache`` — attention layers read/write the page
        stores through the table indirection instead of a contiguous cache.
        """
        x = self._embed(params, tokens).astype(compute_dtype)
        h, _, cache = self._backbone(params, x, ctx=ctx, cache=cache, pos=pos,
                                     paged_tables=block_tables)
        return self._logits(params, h)[:, 0], cache


def build_lm(cfg: ModelConfig) -> LM:
    return LM(cfg)
