"""Attention variants: GQA, local/global (gemma2), MLA (deepseek-v2), cross.

All paths support three execution modes:
  * dense      — one einsum, short sequences
  * chunked    — online-softmax scan over KV (and Q) blocks; O(T) memory,
                 used for 32k prefill and as the portable oracle for the
                 Pallas flash kernel
  * pallas     — kernels/flash_attention.py on TPU (interpret=True on CPU)

KV caches are explicit pytrees; decode writes one position via
``dynamic_update_slice`` and attends under a positional mask.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParallelCtx, apply_rope, softcap, dense_init, split_key

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype=jnp.float32):
    d, hd = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko = split_key(key, 4)
    return {
        "wq": {"w": dense_init(kq, d, cfg.n_heads * hd, dtype)},
        "wk": {"w": dense_init(kk, d, cfg.n_kv_heads * hd, dtype)},
        "wv": {"w": dense_init(kv, d, cfg.n_kv_heads * hd, dtype)},
        "wo": {"w": dense_init(ko, cfg.n_heads * hd, d, dtype)},
    }


def mla_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kl = cfg.kv_lora_rank
    ks = split_key(key, 6)
    return {
        "wq": {"w": dense_init(ks[0], d, h * (dn + dr), dtype)},
        "w_dkv": {"w": dense_init(ks[1], d, kl, dtype)},
        "w_krope": {"w": dense_init(ks[2], d, dr, dtype)},
        "w_uk": dense_init(ks[3], kl, h * dn, dtype),     # raw: used via einsum
        "w_uv": dense_init(ks[4], kl, h * dv, dtype),
        "wo": {"w": dense_init(ks[5], h * dv, d, dtype)},
    }


def cross_attn_init(key, cfg, dtype=jnp.float32):
    return gqa_init(key, cfg, dtype)


# ---------------------------------------------------------------------------
# Core scaled-dot-product attention with GQA grouping
# ---------------------------------------------------------------------------

def _mask_bias(iq, ik, *, causal: bool, window: int):
    """Additive bias from global position indices.

    iq: (len_q,) shared positions, or (B, len_q) per-request positions (the
    continuous-batching engine decodes requests at different offsets in one
    step). Returns (len_q, len_k) resp. (B, len_q, len_k)."""
    d = iq[..., None] - ik
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    if window > 0:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF)


def _q_positions(q_offset, tq):
    """Global query positions; q_offset scalar or (B,) -> (tq,) or (B, tq)."""
    off = jnp.asarray(q_offset)
    if off.ndim == 1:
        return off[:, None] + jnp.arange(tq)
    return off + jnp.arange(tq)


def _add_bias(s, bias):
    """s: (b, hkv, g, tq, tk); bias (tq, tk) or (b, tq, tk)."""
    if bias.ndim == 3:
        return s + bias[:, None, None]
    return s + bias


def _row_update(cache_arr, update, pos):
    """Write one token per batch row at per-row positions.

    cache_arr: (B, L, ...); update: (B, 1, ...); pos: (B,) int32."""
    start = (0,) * (cache_arr.ndim - 2)
    return jax.vmap(
        lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p,) + start)
    )(cache_arr, update, pos)


def _dense_sdpa(q, k, v, *, q_offset, causal, window, cap, scale):
    b, tq, hq, hd = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, tq, hkv, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    s = softcap(s, cap)
    iq = _q_positions(q_offset, tq)
    ik = jnp.arange(tk)
    s = _add_bias(s, _mask_bias(iq, ik, causal=causal, window=window))
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(b, tq, hq, hd)


def _chunked_sdpa(q, k, v, *, q_offset, causal, window, cap, scale,
                  chunk_q: int, chunk_kv: int, skip_masked_blocks: bool = False):
    """FlashAttention-style two-level scan; O(chunk² ) score memory."""
    b, tq, hq, hd = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    cq = min(chunk_q, tq)
    ck = min(chunk_kv, tk)
    # ragged lengths: pad (padded KV keys are masked out via kv_valid; padded
    # queries are sliced off the output) — keeps memory O(chunk²) for shapes
    # like whisper's 1500-frame cross attention
    pad_q = (-tq) % cq
    pad_k = (-tk) % ck
    kv_valid = tk
    if pad_q or pad_k:
        if pad_q:
            q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        if pad_k:
            k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        tq_p, tk_p = tq + pad_q, tk + pad_k
    else:
        tq_p, tk_p = tq, tk
    out = _chunked_sdpa_padded(q, k, v, q_offset=q_offset, causal=causal,
                               window=window, cap=cap, scale=scale,
                               cq=cq, ck=ck, kv_valid=kv_valid)
    return out[:, :tq]


def _chunked_sdpa_padded(q, k, v, *, q_offset, causal, window, cap, scale,
                         cq, ck, kv_valid):
    b, tq, hq, hd = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    nq, nk = tq // cq, tk // ck
    qg = q.reshape(b, nq, cq, hkv, g, hd)
    kc = k.reshape(b, nk, ck, hkv, hd)
    vc = v.reshape(b, nk, ck, hkv, hd)

    def one_q_chunk(qi, q_blk):
        iq = _q_positions(q_offset, cq) + qi * cq
        m0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, hd), jnp.float32)

        def kv_body(carry, inp):
            m, l, acc = carry
            ki, k_blk, v_blk = inp
            ik = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk)
            s = s.astype(jnp.float32) * scale
            s = softcap(s, cap)
            s = _add_bias(s, _mask_bias(iq, ik, causal=causal, window=window))
            s = jnp.where((ik < kv_valid)[None, None, None, None, :],
                          s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(o, 3, 1).reshape(b, cq, hq, hd)   # (b,hkv,g,cq,hd)->(b,cq,hq,hd)

    def q_body(_, inp):
        qi, q_blk = inp
        return None, one_q_chunk(qi, q_blk)

    _, outs = jax.lax.scan(q_body, None,
                           (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, tq, hq, hd).astype(q.dtype)


def sdpa(q, k, v, *, ctx: ParallelCtx, q_offset=0, causal=True, window=0,
         cap=0.0, scale=None):
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    long_seq = max(q.shape[1], k.shape[1]) > ctx.dense_attn_max_seq
    if (ctx.use_pallas and causal and q.shape[1] == k.shape[1]
            and window == 0 and jnp.ndim(q_offset) == 0):
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, scale=scale, cap=cap)
    if long_seq:
        return _chunked_sdpa(q, k, v, q_offset=q_offset, causal=causal,
                             window=window, cap=cap, scale=scale,
                             chunk_q=ctx.attn_chunk_q, chunk_kv=ctx.attn_chunk_kv)
    return _dense_sdpa(q, k, v, q_offset=q_offset, causal=causal,
                       window=window, cap=cap, scale=scale)


# ---------------------------------------------------------------------------
# GQA layer (with optional local window, softcap, rope, KV cache)
# ---------------------------------------------------------------------------

def gqa_empty_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.head_dim
    return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype)}


def gqa_apply(cfg, params, x, *, ctx: ParallelCtx, cos_sin=None,
              cache=None, pos=None, local: bool = False,
              causal: bool = True, paged_tables=None,
              lens=None) -> Tuple[jax.Array, Optional[dict]]:
    from repro.models.linear import linear_apply
    b, t, _ = x.shape
    hd = cfg.head_dim
    q = linear_apply(params["wq"], x).reshape(b, t, cfg.n_heads, hd)
    k = linear_apply(params["wk"], x).reshape(b, t, cfg.n_kv_heads, hd)
    v = linear_apply(params["wv"], x).reshape(b, t, cfg.n_kv_heads, hd)
    if cos_sin is not None:
        cos, sin = cos_sin
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    window = cfg.local_window if local else 0
    scale = cfg.query_scale if cfg.query_scale > 0 else None
    new_cache = None
    if paged_tables is not None:
        # paged serving: the cache leaves are the pool's page stores
        # (num_blocks, block_size, hkv, hd); write the new K/V straight into
        # their pages and attend through the block-table indirection — no
        # contiguous copy of the KV history is ever materialized. t == 1 is
        # a decode step; t > 1 is a chunked suffix prefill writing row i's L
        # tokens at positions pos[i] + j (padded tail tokens past lens[i]
        # land in the row's last partial page or the trash page, hidden by
        # the causal masks until a later decode overwrites them).
        assert pos is not None and jnp.ndim(pos) == 1, \
            "paged path needs per-request positions"
        from repro.kernels import ops as kops
        bs = cache["k"].shape[1]
        p = pos[:, None] + jnp.arange(t)                 # (B, t) positions
        blk = jnp.take_along_axis(paged_tables, p // bs, axis=1)
        kf = cache["k"].at[blk, p % bs].set(k.astype(cache["k"].dtype))
        vf = cache["v"].at[blk, p % bs].set(v.astype(cache["v"].dtype))
        if t == 1:
            o = kops.paged_attention(
                q[:, 0], kf, vf, paged_tables, pos + 1, scale=scale,
                cap=cfg.attn_logit_softcap, window=window,
                impl=ctx.paged_attn_impl)[:, None].astype(q.dtype)
        else:
            assert lens is not None, "chunked paged prefill needs lens"
            o = kops.chunked_prefill(
                q, kf, vf, paged_tables, pos, lens, scale=scale,
                cap=cfg.attn_logit_softcap, window=window,
                impl=ctx.paged_attn_impl).astype(q.dtype)
        y = linear_apply(params["wo"], o.reshape(b, t, cfg.n_heads * hd))
        return y, {"k": kf, "v": vf}
    if cache is not None:
        if pos is None:                                   # prefill: fill [0, t)
            kf = cache["k"].at[:, :t].set(k.astype(cache["k"].dtype))
            vf = cache["v"].at[:, :t].set(v.astype(cache["v"].dtype))
            new_cache = {"k": kf, "v": vf}
            o = sdpa(q, k, v, ctx=ctx, q_offset=0, causal=causal,
                     window=window, cap=cfg.attn_logit_softcap, scale=scale)
        else:                                             # decode: one token
            if jnp.ndim(pos):                             # per-request positions
                kf = _row_update(cache["k"], k.astype(cache["k"].dtype), pos)
                vf = _row_update(cache["v"], v.astype(cache["v"].dtype), pos)
            else:
                kf = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
                vf = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            new_cache = {"k": kf, "v": vf}
            o = sdpa(q, kf.astype(q.dtype), vf.astype(q.dtype), ctx=ctx,
                     q_offset=pos, causal=causal, window=window,
                     cap=cfg.attn_logit_softcap, scale=scale)
    else:
        o = sdpa(q, k, v, ctx=ctx, q_offset=0, causal=causal,
                 window=window, cap=cfg.attn_logit_softcap, scale=scale)
    y = linear_apply(params["wo"], o.reshape(b, t, cfg.n_heads * hd))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA layer (deepseek-v2-lite)
# ---------------------------------------------------------------------------

def mla_empty_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {"c": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype)}


def mla_apply(cfg, params, x, *, ctx: ParallelCtx, cos_sin=None,
              cache=None, pos=None, **_) -> Tuple[jax.Array, Optional[dict]]:
    from repro.models.linear import linear_apply
    b, t, _ = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = 1.0 / ((dn + dr) ** 0.5)
    q = linear_apply(params["wq"], x).reshape(b, t, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    c = linear_apply(params["w_dkv"], x)                       # (b, t, kl)
    k_rope = linear_apply(params["w_krope"], x)[:, :, None, :]  # (b, t, 1, dr)
    if cos_sin is not None:
        cos, sin = cos_sin
        q_rope = apply_rope(q_rope, cos, sin)
        k_rope = apply_rope(k_rope, cos, sin)
    k_rope = k_rope[:, :, 0, :]
    w_uk = params["w_uk"].astype(x.dtype).reshape(cfg.kv_lora_rank, h, dn)
    w_uv = params["w_uv"].astype(x.dtype).reshape(cfg.kv_lora_rank, h, dv)

    if cache is not None and pos is not None:
        # absorbed decode: score in latent space, never materialize per-head K/V
        if jnp.ndim(pos):                                  # per-request positions
            cf = _row_update(cache["c"], c.astype(cache["c"].dtype), pos)
            rf = _row_update(cache["k_rope"],
                             k_rope.astype(cache["k_rope"].dtype), pos)
        else:
            cf = jax.lax.dynamic_update_slice(
                cache["c"], c.astype(cache["c"].dtype), (0, pos, 0))
            rf = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                (0, pos, 0))
        new_cache = {"c": cf, "k_rope": rf}
        q_c = jnp.einsum("bthd,khd->bthk", q_nope, w_uk)       # (b,1,h,kl)
        s = (jnp.einsum("bthk,bsk->bhts", q_c, cf.astype(x.dtype)) +
             jnp.einsum("bthd,bsd->bhts", q_rope, rf.astype(x.dtype)))
        s = s.astype(jnp.float32) * scale
        iq = _q_positions(pos, t)
        ik = jnp.arange(cf.shape[1])
        bias = _mask_bias(iq, ik, causal=True, window=0)
        s = s + (bias[:, None] if bias.ndim == 3 else bias[None, None])
        p = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        ctx_c = jnp.einsum("bhts,bsk->bthk", p, cf.astype(x.dtype))
        o = jnp.einsum("bthk,khd->bthd", ctx_c, w_uv)          # (b,t,h,dv)
    else:
        # train/prefill: expand K/V (MHA after expansion)
        k_nope = jnp.einsum("btk,khd->bthd", c, w_uk)
        v = jnp.einsum("btk,khd->bthd", c, w_uv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, t, h, dr))], -1)
        qq = jnp.concatenate([q_nope, q_rope], -1)
        if dv < dn + dr:                                       # pad V to head dim
            v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
        else:
            v_p = v
        o = sdpa(qq, k, v_p, ctx=ctx, q_offset=0, causal=True, scale=scale)
        o = o[..., :dv]
        new_cache = None
        if cache is not None:                                  # prefill fills cache
            cf = cache["c"].at[:, :t].set(c.astype(cache["c"].dtype))
            rf = cache["k_rope"].at[:, :t].set(k_rope.astype(cache["k_rope"].dtype))
            new_cache = {"c": cf, "k_rope": rf}
    y = linear_apply(params["wo"], o.reshape(b, t, h * dv))
    return y, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_cache_from_encoder(cfg, params, enc_out, dtype=jnp.bfloat16):
    """Precompute K/V over encoder states once per request."""
    from repro.models.linear import linear_apply
    b, s, _ = enc_out.shape
    hd = cfg.head_dim
    k = linear_apply(params["wk"], enc_out).reshape(b, s, cfg.n_kv_heads, hd)
    v = linear_apply(params["wv"], enc_out).reshape(b, s, cfg.n_kv_heads, hd)
    return {"ck": k.astype(dtype), "cv": v.astype(dtype)}


def cross_attn_apply(cfg, params, x, *, ctx: ParallelCtx, enc_out=None,
                     cross_cache=None) -> jax.Array:
    from repro.models.linear import linear_apply
    b, t, _ = x.shape
    hd = cfg.head_dim
    q = linear_apply(params["wq"], x).reshape(b, t, cfg.n_heads, hd)
    if cross_cache is not None:
        k = cross_cache["ck"].astype(q.dtype)
        v = cross_cache["cv"].astype(q.dtype)
    else:
        s = enc_out.shape[1]
        k = linear_apply(params["wk"], enc_out).reshape(b, s, cfg.n_kv_heads, hd)
        v = linear_apply(params["wv"], enc_out).reshape(b, s, cfg.n_kv_heads, hd)
    o = sdpa(q, k, v, ctx=ctx, q_offset=0, causal=False)
    return linear_apply(params["wo"], o.reshape(b, t, cfg.n_heads * hd))
