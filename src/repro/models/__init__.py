"""Model substrate: build any assigned architecture from its ModelConfig."""
from repro.config import ModelConfig
from repro.models.transformer import LM, build_lm
from repro.models.encdec import EncDecLM, build_encdec
from repro.models.common import ParallelCtx, CPU_CTX  # noqa: F401


def build_model(cfg: ModelConfig):
    """Returns an LM or EncDecLM with a uniform init/loss/prefill/decode API."""
    if cfg.family == "encdec":
        return build_encdec(cfg)
    return build_lm(cfg)
