"""Whisper-style encoder–decoder backbone (conv/audio frontend stubbed).

Per the assignment, the modality frontend is a STUB: inputs are precomputed
frame embeddings (B, n_audio_frames, d_model). The transformer backbone is
real: sinusoidal-position encoder (non-causal self-attn), learned-position
decoder (causal self-attn + cross-attn + MLP), both scanned over layers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import ffn as ffn_lib
from repro.models.common import CPU_CTX, ParallelCtx, constrain_act, rmsnorm, \
    rmsnorm_init, dense_init, split_key
from repro.models.linear import linear_apply


def sinusoids(length: int, channels: int):
    log_timescale = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2, dtype=jnp.float32))
    ang = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = split_key(key, 2)
    return {"norm1": rmsnorm_init(cfg.d_model), "attn": attn.gqa_init(k1, cfg, dtype),
            "norm2": rmsnorm_init(cfg.d_model),
            "mlp": ffn_lib.mlp_init(k2, cfg.d_model, cfg.d_ff, glu=False, dtype=dtype)}


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = split_key(key, 3)
    return {"norm1": rmsnorm_init(cfg.d_model), "self": attn.gqa_init(k1, cfg, dtype),
            "norm2": rmsnorm_init(cfg.d_model),
            "cross": attn.cross_attn_init(k2, cfg, dtype),
            "norm3": rmsnorm_init(cfg.d_model),
            "mlp": ffn_lib.mlp_init(k3, cfg.d_model, cfg.d_ff, glu=False, dtype=dtype)}


@dataclasses.dataclass(frozen=True)
class EncDecLM:
    cfg: ModelConfig

    @property
    def n_enc(self):
        return self.cfg.n_enc_layers or self.cfg.n_layers

    def init(self, key, dtype=jnp.float32):
        cfg = self.cfg
        ks = split_key(key, 3 + self.n_enc + cfg.n_layers)
        params: Dict[str, Any] = {
            "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dtype),
            "pos_dec": (jax.random.normal(ks[1], (cfg.max_seq_len, cfg.d_model),
                                          jnp.float32) * 0.02).astype(dtype),
            "enc_final_norm": rmsnorm_init(cfg.d_model),
            "dec_final_norm": rmsnorm_init(cfg.d_model),
        }
        enc = [_enc_layer_init(ks[3 + i], cfg, dtype) for i in range(self.n_enc)]
        dec = [_dec_layer_init(ks[3 + self.n_enc + i], cfg, dtype)
               for i in range(cfg.n_layers)]
        params["enc"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
        params["dec"] = jax.tree.map(lambda *xs: jnp.stack(xs), *dec)
        return params

    # ------------------------------------------------------------------ enc
    def encode(self, params, frames, *, ctx: ParallelCtx = CPU_CTX):
        cfg = self.cfg
        x = frames + sinusoids(frames.shape[1], cfg.d_model).astype(frames.dtype)

        def body(x, lp):
            x = constrain_act(x, ctx)
            h, _ = attn.gqa_apply(cfg, lp["attn"], rmsnorm(lp["norm1"], x),
                                  ctx=ctx, cos_sin=None, causal=False)
            x = x + h
            x = x + ffn_lib.mlp_apply(lp["mlp"], rmsnorm(lp["norm2"], x), "gelu")
            return constrain_act(x, ctx), None

        x, _ = jax.lax.scan(body, x, params["enc"])
        return rmsnorm(params["enc_final_norm"], x)

    # ------------------------------------------------------------------ dec
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        one = {"self": attn.gqa_empty_cache(cfg, batch, max_len, dtype),
               "cross": {"ck": jnp.zeros((batch, cfg.n_audio_frames,
                                          cfg.n_kv_heads, cfg.head_dim), dtype),
                         "cv": jnp.zeros((batch, cfg.n_audio_frames,
                                          cfg.n_kv_heads, cfg.head_dim), dtype)}}
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (self.cfg.n_layers,) + a.shape), one)

    def _decoder(self, params, x, *, ctx, enc_out=None, cache=None, pos=None,
                 remat: str = "none"):
        cfg = self.cfg

        def body(x, xs):
            lp, c = xs if cache is not None else (xs, None)
            x = constrain_act(x, ctx)
            h, nc_self = attn.gqa_apply(
                cfg, lp["self"], rmsnorm(lp["norm1"], x), ctx=ctx, cos_sin=None,
                cache=None if c is None else c["self"], pos=pos)
            x = x + h
            if c is not None and pos is not None:      # decode: cached cross K/V
                h = attn.cross_attn_apply(cfg, lp["cross"],
                                          rmsnorm(lp["norm2"], x), ctx=ctx,
                                          cross_cache=c["cross"])
                nc_cross = c["cross"]
            else:
                h = attn.cross_attn_apply(cfg, lp["cross"],
                                          rmsnorm(lp["norm2"], x), ctx=ctx,
                                          enc_out=enc_out)
                if c is not None:                      # prefill: fill cross cache
                    nc_cross = attn.cross_cache_from_encoder(
                        cfg, lp["cross"], enc_out, c["cross"]["ck"].dtype)
                else:
                    nc_cross = None
            x = x + h
            x = x + ffn_lib.mlp_apply(lp["mlp"], rmsnorm(lp["norm3"], x), "gelu")
            if cache is not None:
                return x, {"self": nc_self, "cross": nc_cross}
            return x, None

        if remat == "full":
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        elif remat == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        xs = (params["dec"], cache) if cache is not None else params["dec"]
        x, new_cache = jax.lax.scan(body, x, xs)
        return rmsnorm(params["dec_final_norm"], x), new_cache

    def _embed_dec(self, params, tokens, pos0):
        cfg = self.cfg
        t = tokens.shape[1]
        if jnp.ndim(pos0):                  # per-request positions: (B,)
            pe = jax.vmap(lambda p: jax.lax.dynamic_slice_in_dim(
                params["pos_dec"], p, t, axis=0))(pos0)
            return params["embed"][tokens] + pe
        pe = jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos0, t, axis=0)
        return params["embed"][tokens] + pe[None]

    # ---------------------------------------------------------- calibration
    def capture_forward(self, params, batch, calibrator, *,
                        ctx: ParallelCtx = CPU_CTX, compute_dtype=jnp.float32):
        """Unrolled-eager forward streaming linear inputs into R factors.

        Cross-attention K/V layers see encoder outputs as X (the COALA
        weighted norm for those weights is over encoder activations)."""
        cfg = self.cfg
        frames = batch["frames"].astype(compute_dtype)
        x = frames + sinusoids(frames.shape[1], cfg.d_model).astype(frames.dtype)
        for i in range(self.n_enc):
            lp = calibrator.wrap(jax.tree.map(lambda a: a[i], params["enc"]),
                                 f"enc/{i}")
            h, _ = attn.gqa_apply(cfg, lp["attn"], rmsnorm(lp["norm1"], x),
                                  ctx=ctx, cos_sin=None, causal=False)
            x = x + h
            x = x + ffn_lib.mlp_apply(lp["mlp"], rmsnorm(lp["norm2"], x),
                                      "gelu")
        enc_out = rmsnorm(params["enc_final_norm"], x)
        x = self._embed_dec(params, batch["tokens"], 0).astype(compute_dtype)
        for i in range(cfg.n_layers):
            lp = calibrator.wrap(jax.tree.map(lambda a: a[i], params["dec"]),
                                 f"dec/{i}")
            h, _ = attn.gqa_apply(cfg, lp["self"], rmsnorm(lp["norm1"], x),
                                  ctx=ctx, cos_sin=None)
            x = x + h
            x = x + attn.cross_attn_apply(cfg, lp["cross"],
                                          rmsnorm(lp["norm2"], x), ctx=ctx,
                                          enc_out=enc_out)
            x = x + ffn_lib.mlp_apply(lp["mlp"], rmsnorm(lp["norm3"], x),
                                      "gelu")
        return rmsnorm(params["dec_final_norm"], x)

    # ------------------------------------------------------------------ api
    def loss(self, params, batch, *, ctx: ParallelCtx = CPU_CTX,
             remat: str = "none", compute_dtype=jnp.bfloat16, loss_chunk: int = 512):
        tokens = batch["tokens"]
        frames = batch["frames"].astype(compute_dtype)
        enc_out = self.encode(params, frames, ctx=ctx)
        x = self._embed_dec(params, tokens, 0).astype(compute_dtype)
        h, _ = self._decoder(params, x, ctx=ctx, enc_out=enc_out, remat=remat)
        from repro.models.transformer import chunked_ce
        ce = chunked_ce(h[:, :-1], tokens[:, 1:], params["embed"].T,
                        chunk=loss_chunk)
        return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}

    def prefill(self, params, tokens, cache, *, frames=None,
                ctx: ParallelCtx = CPU_CTX, compute_dtype=jnp.bfloat16, **_):
        enc_out = self.encode(params, frames.astype(compute_dtype), ctx=ctx)
        x = self._embed_dec(params, tokens, 0).astype(compute_dtype)
        h, cache = self._decoder(params, x, ctx=ctx, enc_out=enc_out, cache=cache)
        logits = (h[:, -1:] @ params["embed"].T.astype(h.dtype)).astype(jnp.float32)
        return logits, cache

    def decode_step(self, params, tokens, cache, pos, *,
                    ctx: ParallelCtx = CPU_CTX, compute_dtype=jnp.bfloat16):
        x = self._embed_dec(params, tokens, pos).astype(compute_dtype)
        h, cache = self._decoder(params, x, ctx=ctx, cache=cache, pos=pos)
        logits = (h @ params["embed"].T.astype(h.dtype)).astype(jnp.float32)
        return logits[:, 0], cache


def build_encdec(cfg: ModelConfig) -> EncDecLM:
    return EncDecLM(cfg)
