"""Mamba (selective SSM) block — used by jamba's hybrid stack.

Training/prefill run a ``lax.scan`` over time (sequential recurrence — the
faithful baseline; a chunked-parallel scan is a §Perf candidate).
Decode is a single-step state update: cache = {conv window, ssm state} — O(1)
per token, which is what makes the ``long_500k`` cell feasible.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParallelCtx, chunked_scan, dense_init, split_key
from repro.models.linear import linear_apply


def _d_inner(cfg) -> int:
    return cfg.mamba.expand * cfg.d_model


def _dt_rank(cfg) -> int:
    return cfg.mamba.dt_rank or math.ceil(cfg.d_model / 16)


def mamba_init(key, cfg, dtype=jnp.float32):
    d, di, ds, dc = cfg.d_model, _d_inner(cfg), cfg.mamba.d_state, cfg.mamba.d_conv
    dtr = _dt_rank(cfg)
    ks = split_key(key, 6)
    return {
        "in_proj": {"w": dense_init(ks[0], d, 2 * di, dtype)},
        "conv_w": (jax.random.normal(ks[1], (dc, di), jnp.float32)
                   / math.sqrt(dc)).astype(dtype),
        "x_proj": {"w": dense_init(ks[2], di, dtr + 2 * ds, dtype)},
        "dt_proj": {"w": dense_init(ks[3], dtr, di, dtype)},
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32)
                             * (math.log(0.1) - math.log(0.001))
                             + math.log(0.001)), 1e-4))).astype(jnp.float32),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                  (di, 1))).astype(jnp.float32),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": {"w": dense_init(ks[5], di, d, dtype)},
    }


def mamba_empty_cache(cfg, batch: int, dtype=jnp.float32):
    di, ds, dc = _d_inner(cfg), cfg.mamba.d_state, cfg.mamba.d_conv
    return {"conv": jnp.zeros((batch, dc - 1, di), dtype),
            "h": jnp.zeros((batch, di, ds), dtype)}


def _causal_conv(x, conv_w, prepend=None):
    """Depthwise causal conv over time. x: (B, T, di), conv_w: (dc, di)."""
    dc = conv_w.shape[0]
    if prepend is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = prepend.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, T+dc-1, di)
    out = sum(xp[:, i:i + x.shape[1]] * conv_w[i][None, None, :].astype(x.dtype)
              for i in range(dc))
    return out, xp[:, -(dc - 1):]                     # y, new conv window


def _ssm_params(cfg, params, u):
    """u: (..., di) -> dt (softplus), B, C."""
    dtr, ds = _dt_rank(cfg), cfg.mamba.d_state
    proj = linear_apply(params["x_proj"], u)
    dt_in, b, c = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(linear_apply(params["dt_proj"], dt_in).astype(jnp.float32)
                         + params["dt_bias"])
    return dt, b.astype(jnp.float32), c.astype(jnp.float32)


def mamba_apply(cfg, params, x, *, ctx: ParallelCtx, cache=None, pos=None,
                **_) -> Tuple[jax.Array, Optional[dict]]:
    b, t, d = x.shape
    di, ds = _d_inner(cfg), cfg.mamba.d_state
    xz = linear_apply(params["in_proj"], x)
    u, z = jnp.split(xz, 2, axis=-1)                  # (B, T, di) each
    a = -jnp.exp(params["a_log"])                     # (di, ds)

    if cache is not None and pos is not None and t == 1:
        # --- decode: O(1) state update ---------------------------------
        conv_win = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)
        cw = params["conv_w"].astype(u.dtype)
        u_c = jax.nn.silu(jnp.einsum("bci,ci->bi", conv_win, cw))[:, None, :]
        dt, bb, cc = _ssm_params(cfg, params, u_c)    # dt (B,1,di), bb/cc (B,1,ds)
        da = jnp.exp(dt[:, 0, :, None] * a[None])     # (B, di, ds)
        h = cache["h"].astype(jnp.float32) * da + \
            dt[:, 0, :, None] * bb[:, 0, None, :] * u_c[:, 0, :, None].astype(jnp.float32)
        y = jnp.einsum("bis,bs->bi", h, cc[:, 0]) + \
            params["d_skip"] * u_c[:, 0].astype(jnp.float32)
        y = y[:, None, :].astype(x.dtype)
        new_cache = {"conv": conv_win[:, 1:].astype(cache["conv"].dtype),
                     "h": h.astype(cache["h"].dtype)}
    else:
        # --- train/prefill: chunk-rematerialized selective scan ----------
        # Per-step quantities (dt, exp(dt·A), dt·B·u — each (B, di, ds)-sized
        # transients) are computed INSIDE the chunked scan so they are
        # rematerialized in backward instead of stored for all T steps.
        prepend = cache["conv"] if cache is not None else None
        u_conv, conv_win = _causal_conv(u, params["conv_w"], prepend)
        u_c = jax.nn.silu(u_conv)                      # (B, T, di)
        dtr = _dt_rank(cfg)
        proj = linear_apply(params["x_proj"], u_c)     # (B, T, dtr+2ds)
        dt_in, bb, cc = jnp.split(proj, [dtr, dtr + ds], axis=-1)

        h0 = (cache["h"].astype(jnp.float32) if cache is not None
              else jnp.zeros((b, di, ds), jnp.float32))
        dt_w = params["dt_proj"]["w"]

        def step(h, inp):
            u_t, dtin_t, bb_t, cc_t = inp              # (B,di) (B,dtr) (B,ds)²
            dt_t = jax.nn.softplus((dtin_t @ dt_w.astype(dtin_t.dtype))
                                   .astype(jnp.float32) + params["dt_bias"])
            da_t = jnp.exp(dt_t[..., None] * a[None])  # (B, di, ds)
            h = h * da_t + dt_t[..., None] * bb_t[:, None, :].astype(jnp.float32) \
                * u_t[..., None].astype(jnp.float32)
            y_t = jnp.einsum("bis,bs->bi", h, cc_t.astype(jnp.float32))
            return h, y_t

        xs = tuple(jnp.moveaxis(v, 1, 0) for v in (u_c, dt_in, bb, cc))
        h_last, ys = chunked_scan(step, h0, xs, chunk=64)
        y = jnp.moveaxis(ys, 0, 1) + params["d_skip"] * u_c.astype(jnp.float32)
        y = y.astype(x.dtype)
        new_cache = None
        if cache is not None:                         # prefill fills state
            new_cache = {"conv": conv_win.astype(cache["conv"].dtype),
                         "h": h_last.astype(cache["h"].dtype)}

    y = y * jax.nn.silu(z)
    return linear_apply(params["out_proj"], y), new_cache
