"""Dense ⟷ low-rank factored linear layers.

COALA's output is a pair (A, B) with W' = A·B. ``FactoredLinear`` is a
first-class citizen: every projection in the model substrate goes through
``linear_apply`` which dispatches on the param structure, so a compressed
model is just a params pytree where some ``{"w": ...}`` leaves were replaced
by ``{"b_t": ..., "a_t": ...}`` — no model code changes.

Math convention: activations are row vectors, y = x @ W where W: (d_in, d_out).
COALA operates on the (d_out, d_in) "weight matrix" view W_mat = Wᵀ with
W_mat' = A·B, so:   y = x @ W' = x @ (A B)ᵀ = (x @ Bᵀ) @ Aᵀ
and we store  b_t = Bᵀ: (d_in, r),  a_t = Aᵀ: (r, d_out).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def linear_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float = 1.0):
    return {"w": dense_init(key, d_in, d_out, dtype, scale)}


def factored_from_coala(a: jax.Array, b: jax.Array):
    """Build factored params from COALA (A: (d_out, r), B: (r, d_in))."""
    return {"b_t": b.T, "a_t": a.T}


class CaptureDict(dict):
    """A linear-layer param dict wrapped for calibration capture.

    ``linear_apply`` records the eager input activations into the attached
    calibrator's streaming-R accumulator (COALA never stores X itself).
    Only used in unrolled-eager calibration passes — never under jit/scan.
    """
    path: str = ""
    calib = None


def linear_apply(params, x, *, use_kernel: bool = False):
    """y = x @ W (dense), fused low-rank (x @ Bᵀ) @ Aᵀ, or dense + adapter
    when both are present (LoRA-style fine-tuning)."""
    if isinstance(params, CaptureDict) and params.calib is not None:
        params.calib.record(params.path, x)
    y = None
    if "w" in params:
        y = x @ params["w"].astype(x.dtype)
        if "b_t" not in params:
            return y
    if use_kernel:
        from repro.kernels import ops as kops
        lr = kops.lowrank_linear(x, params["b_t"].astype(x.dtype),
                                 params["a_t"].astype(x.dtype))
    else:
        lr = (x @ params["b_t"].astype(x.dtype)) @ params["a_t"].astype(x.dtype)
    return lr if y is None else y + lr


def linear_weight_matrix(params) -> jax.Array:
    """The (d_out, d_in) matrix-view W_mat for compression (COALA's W)."""
    if "w" in params:
        return params["w"].T
    return (params["b_t"] @ params["a_t"]).T


def linear_out_dim(params) -> int:
    return params["w"].shape[1] if "w" in params else params["a_t"].shape[1]


def linear_in_dim(params) -> int:
    return params["w"].shape[0] if "w" in params else params["b_t"].shape[0]


def is_factored(params) -> bool:
    return "b_t" in params


def factored_param_count(d_in: int, d_out: int, rank: int) -> int:
    return rank * (d_in + d_out)


def rank_for_ratio(d_in: int, d_out: int, ratio: float) -> int:
    """Largest rank whose factored cost ≤ ratio · dense cost (≥1)."""
    return max(1, int((ratio * d_in * d_out) // (d_in + d_out)))
