"""repro: COALA reproduction framework (compression + serving + training).

Importing the package installs the jax compatibility shims from
``repro.dist.compat`` (``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.make_mesh(axis_types=…)``) so code written against newer jax APIs —
including the distributed test scenarios that spawn fresh interpreters —
runs on the pinned container jax.
"""
import repro.dist  # noqa: F401  (side effect: compat.install())
