"""PartitionSpec factories for every architecture in ``repro.configs``.

One vocabulary, three mesh axes:

  * ``pod``   — slow cross-pod links (DCN). Parameters are **replicated**
                across pods (the int8+EF gradient compression in
                ``train/grad_compress.py`` owns the cross-pod reduction and
                expects pod-replicated params); batches shard over it.
  * ``data``  — fast intra-pod data parallelism. Batches always shard over
                it; in ``mode="train"`` parameters/optimizer state also
                FSDP-shard over it (ZeRO-3 style).
  * ``model`` — tensor parallelism: column-parallel in-projections,
                row-parallel out-projections, vocab-sharded embedding/head,
                expert-parallel MoE banks (the expert axis shards over
                ``model``, matching the ``shard_map`` MoE path in
                ``models/ffn.py``), and kv-head-sharded attention caches.

Every spec is divisibility-guarded: an axis is only assigned to a tensor
dimension the mesh divides evenly, so the same code serves the 8-fake-device
CPU test meshes and the 512-device production meshes in ``launch/dryrun.py``.
Stacked-layer parameters (under ``blocks`` / ``enc`` / ``dec``) carry their
leading scan axis unsharded.

The public API is exactly what ``launch/train.py``, ``launch/dryrun.py`` and
``tests/test_dist.py`` import: ``param_specs``, ``batch_specs``,
``cache_specs``, ``train_state_specs``, ``to_named``, ``batch_axes_of``.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compat

compat.install()

MODEL_AXIS = "model"
# batch-like axes in mesh-major order; only those present in a mesh apply
BATCH_AXES = ("pod", "data")
# FSDP shards parameters over the intra-pod data axis only — never over
# ``pod`` (grad compression needs pod-replicated params, and the error-state
# spec P("pod", *param_spec) must not mention pod twice)
FSDP_AXES = ("data",)

# role of each named linear, keyed by the last meaningful path component.
# col: (d_in, d_out) with d_out model-sharded (in-projections / up-projections)
# row: (d_in, d_out) with d_in model-sharded (out-projections / down-projections)
_COL_KEYS = frozenset({
    "wq", "wk", "wv",                 # GQA / MLA / cross-attention queries
    "gate", "up", "ff_up",            # GLU MLP + sLSTM feed-forward
    "in_proj",                        # mamba input projection
    "w_dkv", "w_krope",               # MLA latent down-projections
    "w_uk", "w_uv",                   # MLA latent up-projections (raw arrays)
    "x_proj", "dt_proj",              # mamba SSM parameter projections
})
_ROW_KEYS = frozenset({
    "wo", "down", "ff_down",          # attention / MLP output projections
    "out_proj",                       # mamba / xlstm output projection
})
# MoE expert banks: (E, d_in, d_out) stacks, expert axis over ``model``
_EXPERT_KEYS = frozenset({"w_gate", "w_up", "w_down"})
# stacked-layer containers whose leaves carry a leading scan axis
_STACKED_KEYS = frozenset({"blocks", "enc", "dec"})


def batch_axes_of(mesh) -> Tuple[str, ...]:
    """The mesh's batch-parallel axes (``pod``/``data``), mesh order."""
    return tuple(a for a in BATCH_AXES if a in mesh.axis_names)


def to_named(specs, mesh):
    """Map a PartitionSpec tree to a NamedSharding tree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(dim: int, mesh, axes):
    """``axes`` if they evenly divide ``dim`` (and exist on the mesh), else
    None. ``axes`` may be a name or a tuple of names."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return None
    size = _axis_size(mesh, axes)
    if size <= 1 or dim % size:
        return None
    return axes[0] if len(axes) == 1 else axes


def _path_names(path) -> Tuple[str, ...]:
    """jax key path -> plain string components."""
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return tuple(out)


def _role(names: Tuple[str, ...]) -> str:
    """Last meaningful path component (skips the 'w' / factor leaf names)."""
    skip = {"w", "b_t", "a_t"}
    for name in reversed(names):
        if name not in skip:
            return name
    return names[-1] if names else ""


def _with_lead(spec_entries, lead: int) -> P:
    return P(*([None] * lead), *spec_entries)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def param_specs(cfg, params, mesh, *, mode: str = "train"):
    """PartitionSpec tree for a parameter pytree.

    ``mode="train"``  — FSDP over ``data`` *plus* tensor parallelism over
                        ``model`` (ZeRO-3-style fully sharded master).
    ``mode="infer"``  — tensor parallelism only; params replicated over the
                        batch axes (decode never pays FSDP all-gathers).
    """
    if mode not in ("train", "infer"):
        raise ValueError(f"param_specs: unknown mode {mode!r}")
    fsdp = FSDP_AXES if mode == "train" else ()

    def leaf_spec(path, leaf) -> P:
        names = _path_names(path)
        shape = tuple(leaf.shape)
        lead = 1 if any(n in _STACKED_KEYS for n in names) else 0
        body = shape[lead:]
        role = _role(names)

        if role in _EXPERT_KEYS and len(body) == 3:
            # (E, d_in, d_out): expert-parallel over model (ffn.py shard_map)
            e, d_in, _ = body
            return _with_lead((_fit(e, mesh, MODEL_AXIS),
                               _fit(d_in, mesh, fsdp), None), lead)
        if role == "embed" and len(body) == 2:
            # (vocab, d_model): vocab-sharded TP; FSDP over features
            v, d = body
            return _with_lead((_fit(v, mesh, MODEL_AXIS),
                               _fit(d, mesh, fsdp)), lead)
        if role == "lm_head" and len(body) == 2:
            d, v = body
            return _with_lead((_fit(d, mesh, fsdp),
                               _fit(v, mesh, MODEL_AXIS)), lead)
        if role in _COL_KEYS and len(body) == 2:
            d_in, d_out = body
            # factored low-rank pairs: only the dense-facing dim is sharded
            model_dim = None if names[-1] == "b_t" else \
                _fit(d_out, mesh, MODEL_AXIS)
            return _with_lead((_fit(d_in, mesh, fsdp), model_dim), lead)
        if role in _ROW_KEYS and len(body) == 2:
            d_in, d_out = body
            model_dim = None if names[-1] == "a_t" else \
                _fit(d_in, mesh, MODEL_AXIS)
            return _with_lead((model_dim, _fit(d_out, mesh, fsdp)), lead)
        # everything else (norm scales, routers, gates, conv/recurrence
        # params, positional tables) is small: replicate
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def train_state_specs(cfg, state, mesh, *, strategy: str = "fsdp"):
    """Specs for the full train state ``{"params", "opt", ["err"]}``.

    ``fsdp``   — params and AdamW moments fully sharded (ZeRO-3).
    ``zero1``  — params TP-only (replicated over data), moments sharded
                 (ZeRO-1); the hoisted-cast variant (``zero1h``) uses the
                 same state specs plus an ``infer``-mode compute copy, wired
                 by the caller via ``make_train_step(compute_specs=...)``.
    """
    if strategy not in ("fsdp", "zero1", "zero1h"):
        raise ValueError(f"train_state_specs: unknown strategy {strategy!r}")
    opt_specs = param_specs(cfg, state["params"], mesh, mode="train")
    if strategy == "fsdp":
        p_specs = opt_specs
    else:
        p_specs = param_specs(cfg, state["params"], mesh, mode="infer")
    out = {"params": p_specs,
           "opt": {"m": opt_specs, "v": opt_specs, "step": P()}}
    if state.get("err") is not None:
        # error-feedback residuals: explicit leading pod axis over the
        # (pod-free) param specs — see train/grad_compress.py
        out["err"] = jax.tree.map(lambda s: P("pod", *tuple(s)), p_specs,
                                  is_leaf=lambda x: isinstance(x, P))
    return out


# ---------------------------------------------------------------------------
# batches and caches
# ---------------------------------------------------------------------------

def batch_specs(cfg, batch, mesh):
    """Batch leaves (tokens / frames / vision_embeds): row-sharded over the
    batch axes, features replicated."""
    baxes = batch_axes_of(mesh)

    def leaf_spec(leaf) -> P:
        if not getattr(leaf, "ndim", 0):
            return P()
        return P(_fit(leaf.shape[0], mesh, baxes),
                 *([None] * (leaf.ndim - 1)))

    return jax.tree.map(leaf_spec, batch)


def cache_specs(cfg, cache, mesh):
    """KV / recurrent cache leaves: batch-sharded rows; attention KV pages
    additionally shard the kv-head axis over ``model`` (GQA); MLA latent
    caches ``(B, L, kv_lora_rank)`` keep the latent dim replicated — it is
    shared across heads by construction.

    Handles both LM caches (``prefix`` unstacked + ``blocks`` with a leading
    scan axis) and enc-dec caches (every leaf stacked over layers).
    """
    baxes = batch_axes_of(mesh)

    def leaf_spec(path, leaf) -> P:
        names = _path_names(path)
        stacked = 1 if (cfg.is_encdec or "blocks" in names) else 0
        body = tuple(leaf.shape[stacked:])
        entries = [_fit(body[0], mesh, baxes)] + [None] * (len(body) - 1)
        if names[-1] in ("k", "v", "ck", "cv") and len(body) == 4:
            entries[2] = _fit(body[2], mesh, MODEL_AXIS)   # kv-head axis
        return _with_lead(entries, stacked)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)
