"""``repro.dist``: mesh sharding + distributed calibration subsystem.

Importing this package installs the jax compatibility shims (see
``repro.dist.compat``) so the sharding/shard_map code paths run on the
pinned container jax. Submodules:

  * ``sharding``  — PartitionSpec trees for params / train state / batches /
                    KV caches across every config in ``repro.configs``
  * ``calibrate`` — data-parallel Gram-free COALA calibration (butterfly
                    TSQR reduction of per-shard R factors)
  * ``compat``    — jax.shard_map / AxisType / make_mesh(axis_types=) shims
"""
from repro.dist import compat

compat.install()
