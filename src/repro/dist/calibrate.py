"""Data-parallel Gram-free COALA calibration (paper §4.2, scaled out).

The calibration matrix ``X`` (features × tokens) for a production corpus
never fits on one device. The paper's answer — and this module's — is that
only the n×n ``R`` factor of ``Xᵀ`` is ever needed (Prop. 2), and R factors
compose by QR-stacking. So calibration shards the *token rows* over the
``data`` mesh axis:

  1. every shard streams its own activation rows into per-layer local R
     factors (``core.calibrate.Calibrator`` — the same TSQR streaming as the
     single-device path, never materializing X);
  2. the per-shard R factors reduce with the butterfly
     ``core.tsqr.distributed_tsqr_r`` inside ``shard_map`` — log2(shards)
     ppermute+QR rounds, after which every device holds the identical full
     R. No Gram matrix, no gather, O(n²) per-device state.

Because R is unique for full-rank input under the non-negative-diagonal sign
convention, the combined R matches the single-device ``Calibrator`` output
for ANY shard count — entrywise within fp32 roundoff when X is
well-conditioned, and in general up to a left-orthogonal factor whose
entrywise footprint scales with cond(X) but under which COALA's weighted
projection (and the Gram form RᵀR) is exactly invariant. Shard-count
invariance is a testable contract (``tests/test_dist_calibrate.py``) in both
senses, not a hope. The Gram
path squares the condition number before it ever reduces; the QR path
reduces already-orthogonalized factors, which is why ill-conditioned
calibration survives sharding here and not in Gram-based baselines.

On this CPU container the per-shard capture runs as a host loop over the
shards of each batch (one fake device per shard); on a real fleet each host
runs step 1 on its local data and only step 2 touches the interconnect.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Iterable, List

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.calibrate import Calibrator
from repro.core.tsqr import distributed_tsqr_r, qr_r, square_r, tsqr_tree
from repro.dist import compat
from repro.obs import trace

compat.install()


def split_batch(batch: dict, n_shards: int) -> List[dict]:
    """Row-split every batch leaf into ``n_shards`` equal sub-batches."""
    b = jax.tree.leaves(batch)[0].shape[0]
    if b % n_shards:
        raise ValueError(f"batch rows {b} not divisible by {n_shards} shards")
    per = b // n_shards
    return [jax.tree.map(lambda x: x[s * per:(s + 1) * per], batch)
            for s in range(n_shards)]


@functools.lru_cache(maxsize=None)
def _butterfly_reduce_fn(mesh, axis: str):
    """One jitted butterfly-reduce per (mesh, axis) — ``calibrate_sharded``
    calls it once per captured layer, and a fresh closure each time would
    re-trace and re-compile the identical (n, n) program per layer."""
    return jax.jit(jax.shard_map(
        lambda r: distributed_tsqr_r(r[0], axis),
        mesh=mesh, in_specs=P(axis, None, None), out_specs=P(),
        check_vma=False))


def combine_r_shards(r_stack: jax.Array, mesh, axis: str = "data") -> jax.Array:
    """Reduce per-shard R factors ``(S, n, n)`` to one full R on-mesh.

    Runs the butterfly TSQR over ``axis`` inside ``shard_map``: each device
    holds its shard's R, pairs XOR-wise through ``ppermute``, and after
    log2(S) QR rounds every device holds the identical combined R (returned
    replicated). ``S`` must equal ``mesh.shape[axis]`` (power of two).
    """
    size = mesh.shape[axis]
    if r_stack.shape[0] != size:
        raise ValueError(
            f"r_stack has {r_stack.shape[0]} shards, mesh axis {axis!r} "
            f"has size {size}")
    with trace.span("calib.butterfly_reduce", shards=size,
                    n=int(r_stack.shape[-1])):
        if size == 1:
            return square_r(qr_r(r_stack[0]))
        return _butterfly_reduce_fn(mesh, axis)(r_stack)


@dataclasses.dataclass
class ShardedCalibration:
    """Result of ``calibrate_sharded`` — duck-types the ``Calibrator`` API
    that ``core.compress.compress_model`` consumes."""

    factors: Dict[str, jax.Array]
    tokens: Dict[str, int]
    n_shards: int

    def r_factors(self) -> Dict[str, jax.Array]:
        return dict(self.factors)

    def tokens_seen(self) -> Dict[str, int]:
        return dict(self.tokens)


def calibrate_sharded(model, params, batches: Iterable[dict], mesh, *,
                      axis: str = "data") -> ShardedCalibration:
    """Shard calibration rows over ``mesh`` axis ``axis``; butterfly-reduce
    per-shard R factors. Returns per-layer full R factors (replicated).

    Paths that only some shards observed (MoE experts routed on a subset of
    shards) are combined host-side with the serial TSQR tree over the shards
    that saw them — still Gram-free, just off the collective fast path.
    """
    n = mesh.shape[axis]
    shard_cals = [Calibrator() for _ in range(n)]
    n_batches = 0
    for batch in batches:
        n_batches += 1
        for cal, sub in zip(shard_cals, split_batch(batch, n)):
            model.capture_forward(params, sub, cal)
    if n_batches == 0:
        raise ValueError("calibrate_sharded: no calibration batches")

    all_paths: List[str] = []
    for cal in shard_cals:
        for p in cal.streams:
            if p not in all_paths:
                all_paths.append(p)

    factors: Dict[str, jax.Array] = {}
    tokens: Dict[str, int] = {}
    for path in all_paths:
        locals_ = [square_r(cal.streams[path].r)
                   for cal in shard_cals if path in cal.streams]
        tokens[path] = sum(cal.streams[path].tokens_seen
                           for cal in shard_cals if path in cal.streams)
        if len(locals_) == n:
            factors[path] = combine_r_shards(jnp.stack(locals_), mesh,
                                             axis=axis)
        else:                      # partial coverage (per-expert MoE paths)
            factors[path] = square_r(tsqr_tree(locals_))
    return ShardedCalibration(factors=factors, tokens=tokens, n_shards=n)
