"""jax API compatibility shims for the pinned container jax (0.4.37).

The sharding subsystem — and the launchers/tests written against it — use
three jax APIs that postdate the pin:

  * ``jax.shard_map``              — promoted out of ``jax.experimental`` with
                                     ``check_vma=`` (renamed from
                                     ``check_rep=``) and ``axis_names=``
                                     (manual axes; the pinned spelling is the
                                     complement set ``auto=``)
  * ``jax.sharding.AxisType``      — Auto/Explicit/Manual mesh axis types
  * ``jax.make_mesh(axis_types=…)`` — the new kwarg on mesh construction

``install()`` grafts equivalents onto the jax namespace **only where the
running jax lacks them**, so the same repo code (and the subprocess test
scenarios that call ``jax.shard_map`` / ``jax.sharding.AxisType`` directly)
runs on both sides of the pin. On a newer jax every branch is a no-op.

Importing any ``repro`` module installs the shims (see ``repro/__init__.py``);
install() is idempotent.
"""
from __future__ import annotations

import enum
import inspect

import jax

try:  # pinned location (jax <= 0.4.x); absent once shard_map moves to core
    from jax.experimental.shard_map import shard_map as _experimental_shard_map
except ImportError:  # pragma: no cover - newer jax, shim never needed
    _experimental_shard_map = None


class AxisType(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` (newer jax).

    The pinned GSPMD treats every mesh axis as what newer jax calls ``Auto``;
    the enum exists so call sites can *spell* axis types portably. Code that
    branches on ``Manual`` (e.g. ``constrain_act``) only does so through
    ``get_abstract_mesh``, which the pinned jax lacks — those branches fall
    back gracefully.
    """

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, check_rep=None,
              axis_names=None, auto=None):
    """Newer-jax ``jax.shard_map`` signature on top of the pinned one.

    ``check_vma`` maps to ``check_rep``; ``axis_names`` (the set of axes the
    body is manual over) maps to its complement ``auto`` (the axes left to
    GSPMD). Passing both old and new spellings of either knob is an error.
    """
    if check_vma is not None and check_rep is not None:
        raise TypeError("pass check_vma or check_rep, not both")
    if axis_names is not None and auto is not None:
        raise TypeError("pass axis_names or auto, not both")
    rep = check_rep if check_rep is not None else (
        check_vma if check_vma is not None else True)
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _experimental_shard_map(f, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_rep=rep,
                                   auto=frozenset(auto or ()))


def _axis_size(axis_name):
    """Newer-jax ``jax.lax.axis_size``: static size of a bound mesh axis.

    On the pinned jax, ``jax.core.axis_frame(name)`` returns the size as a
    plain int inside shard_map/pmap bodies — exactly the static value the
    butterfly TSQR needs to unroll its log2(size) rounds.
    """
    return jax.core.axis_frame(axis_name)


def _wrap_make_mesh(real_make_mesh):
    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None,
                  **kwargs):
        # the pinned GSPMD has no axis types — every axis behaves as Auto;
        # accept and drop the kwarg so newer-jax call sites parse
        del axis_types
        return real_make_mesh(axis_shapes, axis_names, devices=devices,
                              **kwargs)
    make_mesh.__doc__ = real_make_mesh.__doc__
    make_mesh._repro_compat = True
    return make_mesh


def install() -> None:
    """Idempotently install the shims onto the jax namespace."""
    if not hasattr(jax, "shard_map") and _experimental_shard_map is not None:
        jax.shard_map = shard_map
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = AxisType
    if not hasattr(jax.lax, "axis_size"):
        jax.lax.axis_size = _axis_size
    if not getattr(jax.make_mesh, "_repro_compat", False) and \
            "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        jax.make_mesh = _wrap_make_mesh(jax.make_mesh)
