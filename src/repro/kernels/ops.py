"""Public jit'd wrappers for the Pallas kernels.

On the CPU container the kernels execute in ``interpret=True`` mode (the
kernel body runs as traced JAX ops — bit-for-bit the same program the Mosaic
compiler would lower on TPU). On TPU they compile natively.
"""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import gram_accum as _ga
from repro.kernels import lowrank_linear as _ll
from repro.kernels.compat import tpu_compiler_params  # noqa: F401  (re-export)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def lowrank_linear(x, b_t, a_t, **kw):
    kw.setdefault("interpret", _interpret())
    return _ll.lowrank_linear(x, b_t, a_t, **kw)


def gram_accum(a, **kw):
    kw.setdefault("interpret", _interpret())
    return _ga.gram_accum(a, **kw)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interpret())
    return _fa.flash_attention(q, k, v, **kw)
