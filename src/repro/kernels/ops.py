"""Public jit'd wrappers for the Pallas kernels.

On the CPU container the kernels execute in ``interpret=True`` mode (the
kernel body runs as traced JAX ops — bit-for-bit the same program the Mosaic
compiler would lower on TPU). On TPU they compile natively.
"""
from __future__ import annotations

import jax

from repro.kernels import chunked_prefill as _cp
from repro.kernels import flash_attention as _fa
from repro.kernels import gram_accum as _ga
from repro.kernels import lowrank_linear as _ll
from repro.kernels import paged_attention as _pa
from repro.kernels.compat import tpu_compiler_params  # noqa: F401  (re-export)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def lowrank_linear(x, b_t, a_t, **kw):
    kw.setdefault("interpret", _interpret())
    return _ll.lowrank_linear(x, b_t, a_t, **kw)


def gram_accum(a, **kw):
    kw.setdefault("interpret", _interpret())
    return _ga.gram_accum(a, **kw)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interpret())
    return _fa.flash_attention(q, k, v, **kw)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    impl=None, **kw):
    """Paged-attention decode dispatch.

    impl: None/"auto" — native Pallas on TPU, ``jax.nn`` reference
    elsewhere (interpret mode is far too slow for a per-step hot path);
    "pallas" — force the kernel (native on TPU, interpret elsewhere, used
    by CI parity tests); "ref" — force the jax.nn fallback.
    """
    if impl in (None, "auto"):
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _pa.paged_attention_ref(q, k_pages, v_pages, block_tables,
                                       lengths, **kw)
    assert impl == "pallas", f"unknown paged-attention impl: {impl}"
    return _pa.paged_attention(q, k_pages, v_pages, block_tables, lengths,
                               interpret=_interpret(), **kw)


def chunked_prefill(q, k_pages, v_pages, block_tables, starts, lens, *,
                    impl=None, **kw):
    """Chunked-prefill (batched paged suffix prefill) dispatch.

    Same policy as ``paged_attention``: impl None/"auto" — native Pallas on
    TPU, ``jax.nn`` reference elsewhere (interpret mode is far too slow for
    a hot path); "pallas" — force the kernel (native on TPU, interpret
    elsewhere, used by CI parity tests); "ref" — force the jax.nn fallback.
    """
    if impl in (None, "auto"):
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _cp.chunked_prefill_ref(q, k_pages, v_pages, block_tables,
                                       starts, lens, **kw)
    assert impl == "pallas", f"unknown chunked-prefill impl: {impl}"
    return _cp.chunked_prefill(q, k_pages, v_pages, block_tables, starts,
                               lens, interpret=_interpret(), **kw)
