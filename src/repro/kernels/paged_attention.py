"""Paged-attention decode Pallas kernel: block-table indirection, no gather.

One decode step attends a single query token per request against that
request's KV history, which lives scattered across fixed-size *pages* of a
shared block pool (``serve/paged_cache.py``) and is addressed through a
per-request block table. The previous read path gathered every request's
pages into a contiguous ``(B, T, Hkv, hd)`` view before calling attention —
a full-cache copy per decode step. This kernel reads the indirection
directly:

  * ``block_tables (B, nb)`` and ``lengths (B,)`` ride in SMEM as
    scalar-prefetch arguments (``pltpu.PrefetchScalarGridSpec``), available
    before the kernel body runs so they can steer the DMA;
  * the K/V BlockSpec index maps resolve ``tables[b, i]`` per grid step, so
    each KV page is fetched from HBM exactly once, block-by-block — HBM
    traffic is O(tokens attended), never O(pool);
  * grid ``(B, Hkv, nb)`` with the page axis innermost ("arbitrary"):
    online-softmax state (m, l, acc) for the G = Hq/Hkv query heads sharing
    a KV head lives in VMEM scratch and is carried across pages — GQA means
    K/V traffic scales with Hkv, not Hq;
  * pages past ``ceil(len/bs)`` and (with a sliding window) pages wholly
    below the window are skipped via ``pl.when`` — padding rows in a
    bucketed batch (length ≤ 1, table full of the trash block) cost one
    masked page at most.

``interpret=True`` runs the same program as traced JAX ops, so CPU CI
executes the kernel body bit-for-bit; ``paged_attention_ref`` is the plain
``jax.nn`` fallback for backends without Pallas support (and the parity
oracle in tests). ``kernels/chunked_prefill.py`` is this kernel's
prefill-shaped sibling (batched suffix prefill over the same pages);
docs/kernels.md documents both grids and the SMEM prefetch layout, and
docs/serving.md the page/block/bucket vocabulary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *,
            scale: float, cap: float, window: int, bs: int, nb: int):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    live = i * bs < length                     # page holds valid positions
    if window > 0:                             # page not wholly below window
        live &= (i + 1) * bs > length - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0]                        # (G, hd)
        k = k_ref[0, :, 0]                     # (bs, hd)
        v = v_ref[0, :, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if cap > 0:
            s = cap * jnp.tanh(s / cap)
        ik = i * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = ik < length                       # causal: q sits at length-1
        if window > 0:
            ok &= (length - 1 - ik) < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]                    # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == nb - 1)
    def _finalize():
        # zero-length rows (bucket padding) finalize with l == 0 -> output 0
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "cap", "window",
                                             "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    scale=None, cap: float = 0.0, window: int = 0,
                    interpret: bool = False):
    """Decode-step attention over a paged KV cache.

    q: (B, Hq, hd) — one query token per request, already rotary-embedded.
    k_pages/v_pages: (num_blocks, bs, Hkv, hd) — the shared block pool.
    block_tables: (B, nb) int32 — physical page ids per request, ragged rows
      padded with the trash block (0).
    lengths: (B,) int32 — valid positions per request (query at length-1);
      0 marks a bucket-padding row and yields a zero output row.

    Returns (B, Hq, hd) in q.dtype.
    """
    b, hq, hd = q.shape
    nb_total, bs, hkv, _ = k_pages.shape
    g = hq // hkv
    nb = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(b, hkv, g, hd)
    tables = block_tables.astype(jnp.int32)
    lens = lengths.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # tables, lengths -> SMEM
        grid=(b, hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, h, i, tbl, ln: (bi, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda bi, h, i, tbl, ln: (tbl[bi, i], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda bi, h, i, tbl, ln: (tbl[bi, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, h, i, tbl, ln: (bi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),   # running max m
            pltpu.VMEM((g, 1), jnp.float32),   # running denom l
            pltpu.VMEM((g, hd), jnp.float32),  # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, cap=cap, window=window,
                          bs=bs, nb=nb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(tables, lens, qg, k_pages, v_pages)
    return out.reshape(b, hq, hd)


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        scale=None, cap: float = 0.0, window: int = 0):
    """``jax.nn`` fallback for backends without Pallas, and the test oracle.

    Gathers only the pages named by the block tables (O(tokens attended),
    inside the surrounding jit) and runs a masked softmax in fp32.
    """
    b, hq, hd = q.shape
    bs, hkv = k_pages.shape[1], k_pages.shape[2]
    g = hq // hkv
    nb = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    k = k_pages[block_tables].reshape(b, nb * bs, hkv, hd)
    v = v_pages[block_tables].reshape(b, nb * bs, hkv, hd)
    qg = q.reshape(b, hkv, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if cap > 0:
        s = cap * jnp.tanh(s / cap)
    ik = jnp.arange(nb * bs)
    ok = ik[None] < lengths[:, None]
    if window > 0:
        ok &= (lengths[:, None] - 1 - ik[None]) < window
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.maximum(m, NEG_INF / 2))   # all-masked rows -> p ~ 0
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgs,bskd->bkgd", p / jnp.maximum(l, 1e-30),
                   v.astype(jnp.float32))
    return o.reshape(b, hq, hd).astype(q.dtype)
