"""JAX version-compat helpers for the Pallas TPU kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kw):
    """``pltpu.CompilerParams`` (new JAX) vs ``pltpu.TPUCompilerParams``
    (<= 0.4.x). Both take the same kwargs."""
    cls = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams")
    return cls(**kw)
