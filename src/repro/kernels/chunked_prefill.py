"""Chunked-prefill Pallas kernel: batched suffix prefill over the paged pool.

Batched prefill (``serve/engine.py``) admits a group of requests whose
prompt *suffixes* (the part their cached prefix does not cover) land in the
same length bucket and computes them in one call. Before this kernel the
read path gathered every row's pages into a contiguous ``(B, T, Hkv, hd)``
view and ran dense attention against it — a full per-row cache copy per
prefill, the same tax the decode path shed in ``paged_attention.py``. This
kernel is that kernel's prefill-shaped sibling and reads the block-table
indirection directly:

  * ``block_tables (B, nb)``, ``starts (B,)`` (each row's cached-prefix
    length = its first query's global position) and ``lens (B,)`` (valid
    suffix tokens per row) ride in SMEM as scalar-prefetch arguments
    (``pltpu.PrefetchScalarGridSpec``), available before the body runs so
    they steer the DMA and the masks;
  * grid ``(B, Hkv, q_chunks, pages)`` with the page axis innermost
    ("arbitrary"): each program attends one ``block_q``-token query chunk of
    one row against one KV page; online-softmax state (m, l, acc) for the
    chunk's ``block_q x G`` queries (G = Hq/Hkv heads sharing a KV head)
    lives in VMEM scratch and is carried across pages;
  * per-row causal masks are *offset by the cached-prefix length*: query j
    of row b sits at global position ``starts[b] + j`` and attends keys
    ``[0, starts[b] + j]`` — so a row reuses its cached prefix KV without
    recomputing it;
  * pages wholly above the chunk's causal diagonal, wholly below its
    sliding window, or past the row's written length are skipped via
    ``pl.when`` — bucket-padding rows and padded query chunks cost at most
    one masked page;
  * sliding-window and logit-softcap masking match ``paged_attention``.

The suffix K/V themselves are written into their pages by the surrounding
jit (``models/attention.py`` scatters row b's L new tokens at positions
``starts[b] + j`` through the table, the decode write idiom generalized to
L tokens; the page stores are donated, so XLA updates them in place) —
the kernel then reads pages that already contain the new tokens.

``interpret=True`` runs the same program as traced JAX ops on CPU CI;
``chunked_prefill_ref`` is the ``jax.nn`` fallback for backends without
Pallas (the CPU serving default) and the parity oracle in tests. See
``docs/kernels.md`` for the grid/SMEM layout side by side with the decode
kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(tbl_ref, start_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *,
            scale: float, cap: float, window: int,
            bs: int, bq: int, nb: int):
    b = pl.program_id(0)
    qi = pl.program_id(2)
    i = pl.program_id(3)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = start_ref[b]
    total = start + len_ref[b]             # row's written length (prefix+suffix)
    q_lo = start + qi * bq                 # global position of chunk's first query
    live = q_lo < total                    # chunk holds at least one valid query
    live &= i * bs < total                 # page not past the written length
    live &= i * bs <= q_lo + bq - 1        # page not wholly above the diagonal
    if window > 0:                         # page not wholly below the window
        live &= (i + 1) * bs > q_lo + 1 - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0]                    # (bq, G, hd)
        k = k_ref[0, :, 0]                 # (bs, hd)
        v = v_ref[0, :, 0]
        g, hd = q.shape[1], q.shape[2]
        s = jnp.dot(q.reshape(bq * g, hd), k.T,
                    preferred_element_type=jnp.float32) * scale
        s = s.reshape(bq, g, bs)
        if cap > 0:
            s = cap * jnp.tanh(s / cap)
        iq = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        ok = iq < total                    # padded queries (j >= lens) -> 0 rows
        ik = i * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        ok &= ik <= iq                     # causal, offset by the cached prefix
        if window > 0:
            ok &= (iq - ik) < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]                # (bq, G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        # fully-masked query rows keep m == NEG_INF; shift the exponent so
        # they contribute p = 0 (exp(NEG_INF - NEG_INF) would be 1)
        p = jnp.exp(s - jnp.maximum(m_new, NEG_INF / 2))
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.reshape(bq * g, bs).astype(v.dtype), v,
            preferred_element_type=jnp.float32).reshape(bq, g, hd)
        m_ref[...] = m_new

    @pl.when(i == nb - 1)
    def _finalize():
        # rows that attended nothing (query padding, zero-length rows)
        # finalize with l == 0 -> output 0
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "cap", "window",
                                             "block_q", "interpret"))
def chunked_prefill(q, k_pages, v_pages, block_tables, starts, lens, *,
                    scale=None, cap: float = 0.0, window: int = 0,
                    block_q: int = 16, interpret: bool = False):
    """Batched suffix-prefill attention over a paged KV cache.

    q: (B, L, Hq, hd) — each row's suffix queries, rotary already applied,
      right-padded to the shared length bucket ``L``.
    k_pages/v_pages: (num_blocks, bs, Hkv, hd) — the shared page stores,
      already holding the new suffix K/V (the caller scatters them in).
    block_tables: (B, nb) int32 — physical page ids per request, ragged rows
      padded with the trash page (0).
    starts: (B,) int32 — cached-prefix length per row (the global position
      of its first suffix query).
    lens: (B,) int32 — valid suffix tokens per row; query rows past
      ``lens[b]`` (bucket padding) return zeros, as do rows with
      ``lens[b] == 0``.

    Returns (B, L, Hq, hd) in q.dtype.
    """
    b, lq, hq, hd = q.shape
    nb_total, bs, hkv, _ = k_pages.shape
    g = hq // hkv
    nb = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    bq = min(block_q, lq)
    pad = (-lq) % bq
    if pad:
        # padded queries sit at global positions >= starts + lens, so the
        # validity mask zeroes them without any extra bookkeeping
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = (lq + pad) // bq
    qg = q.reshape(b, nq * bq, hkv, g, hd).transpose(0, 2, 1, 3, 4)
    tables = block_tables.astype(jnp.int32)
    st = starts.astype(jnp.int32)
    ln = lens.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,             # tables, starts, lens -> SMEM
        grid=(b, hkv, nq, nb),
        in_specs=[
            pl.BlockSpec((1, 1, bq, g, hd),
                         lambda bi, h, qi, i, tbl, s, ln: (bi, h, qi, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda bi, h, qi, i, tbl, s, ln: (tbl[bi, i], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, hd),
                         lambda bi, h, qi, i, tbl, s, ln: (tbl[bi, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, g, hd),
                               lambda bi, h, qi, i, tbl, s, ln: (bi, h, qi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, g, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, g, 1), jnp.float32),   # running denom l
            pltpu.VMEM((bq, g, hd), jnp.float32),  # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, cap=cap, window=window,
                          bs=bs, bq=bq, nb=nb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, nq * bq, g, hd), q.dtype),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
    )(tables, st, ln, qg, k_pages, v_pages)
    out = out.transpose(0, 2, 1, 3, 4).reshape(b, nq * bq, hq, hd)
    return out[:, :lq]


def chunked_prefill_ref(q, k_pages, v_pages, block_tables, starts, lens, *,
                        scale=None, cap: float = 0.0, window: int = 0):
    """``jax.nn`` fallback for backends without Pallas, and the test oracle.

    Gathers only the pages named by the block tables (O(tokens attended),
    inside the surrounding jit) and runs a masked softmax in fp32 with the
    same per-row prefix-offset causal semantics as the kernel.
    """
    b, lq, hq, hd = q.shape
    bs, hkv = k_pages.shape[1], k_pages.shape[2]
    g = hq // hkv
    nb = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    k = k_pages[block_tables].reshape(b, nb * bs, hkv, hd)
    v = v_pages[block_tables].reshape(b, nb * bs, hkv, hd)
    qg = q.reshape(b, lq, hkv, g, hd)
    s = jnp.einsum("blkgd,bskd->bkgls", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if cap > 0:
        s = cap * jnp.tanh(s / cap)
    iq = starts[:, None] + jnp.arange(lq)              # (B, L) global positions
    ik = jnp.arange(nb * bs)
    ok = iq[..., None] < (starts + lens)[:, None, None]  # mask padded queries
    ok &= ik[None, None] <= iq[..., None]                # prefix-offset causal
    if window > 0:
        ok &= (iq[..., None] - ik[None, None]) < window
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jnp.maximum(m, NEG_INF / 2))       # all-masked rows -> ~0
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgls,bskd->blkgd", p / jnp.maximum(l, 1e-30),
                   v.astype(jnp.float32))
    return o.reshape(b, lq, hq, hd).astype(q.dtype)
