"""Pallas TPU kernels for the perf-critical hot spots (+ ops.py wrappers,
ref.py oracles). Validated in interpret=True mode on CPU."""
from repro.kernels import ops, ref  # noqa: F401
