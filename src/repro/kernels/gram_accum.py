"""Blocked Gram accumulation Pallas kernel:  G = Σ_k A[k]ᵀ A[k].

The memory-bounded Gram path the paper's baselines (SVD-LLM / SVD-LLM v2)
rely on: activations stream through in token chunks and the n×n Gram matrix
accumulates in fp32. On TPU this is a K-reduction matmul: grid
(n/bi, n/bj, K/bk) with the output block revisited across the k dimension
("arbitrary" semantics) and initialized at k == 0.

VMEM per program (bi=bj=256, bk=512, bf16 in / fp32 acc):
  a_i 0.25MB + a_j 0.25MB + acc 0.25MB ≈ 0.75MB — deliberately small so many
programs can overlap DMA with MXU work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import tpu_compiler_params


def _kernel(ai_ref, aj_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(ai_ref[...].T, aj_ref[...],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("block_i", "block_j", "block_k", "interpret"))
def gram_accum(a, *, block_i: int = 256, block_j: int = 256,
               block_k: int = 512, interpret: bool = False):
    """a: (k_tokens, n) chunk of Xᵀ -> (n, n) fp32 Gram contribution aᵀa."""
    k_tokens, n = a.shape
    bi = min(block_i, n)
    bj = min(block_j, n)
    bk = min(block_k, k_tokens)
    if n % bi or n % bj or k_tokens % bk:
        return a.T.astype(jnp.float32) @ a.astype(jnp.float32)
    grid = (n // bi, n // bj, k_tokens // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bi), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bj), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(a, a)
