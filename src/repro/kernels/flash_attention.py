"""Causal FlashAttention-2 Pallas kernel with GQA and logit softcap.

Grid: (B·Hq, Tq/bq, Tk/bk); the KV axis is the innermost ("arbitrary")
dimension so the online-softmax state (m, l, acc) lives in VMEM scratch and
is carried across KV blocks. GQA is expressed in the BlockSpec index maps:
the K/V block index maps a query head h to its KV head h // (Hq // Hkv), so
K/V HBM traffic scales with Hkv, not Hq.

Block-causal skip: KV blocks strictly above the diagonal are never computed
(``pl.when``), so FLOPs match the true causal half, unlike the masked dense
path.

VMEM per program (bq=bk=128, hd=128, bf16): q/k/v 32KB·3 + acc fp32 64KB +
m/l 1KB ≈ 160KB — deliberately small so many programs overlap DMA with MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, cap: float, bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ki * bk <= qi * bq + bq - 1)          # skip fully-masked blocks
    def _compute():
        q = q_ref[0]                               # (bq, hd)
        k = k_ref[0]                               # (bk, hd)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if cap > 0:
            s = cap * jnp.tanh(s / cap)
        iq = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        ik = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(iq >= ik, s, NEG_INF)
        m_prev = m_ref[...]                        # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "cap", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, scale=None, cap: float = 0.0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, T, Hq, hd); k/v: (B, T, Hkv, hd); causal. Returns (B, T, Hq, hd)."""
    b, t, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    bq = min(block_q, t)
    bk = min(block_k, t)
    if t % bq or t % bk:
        from repro.kernels.ref import flash_attention_ref
        return flash_attention_ref(q, k, v, scale=scale, cap=cap)
    nq, nk = t // bq, t // bk
    qh = q.transpose(0, 2, 1, 3).reshape(b * hq, t, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(b * hkv, t, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(b * hkv, t, hd)

    def kv_index(bh, qi, ki):
        return ((bh // hq) * hkv + (bh % hq) // g, ki, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, cap=cap, bq=bq, bk=bk, nk=nk),
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, t, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),       # running max m
            pltpu.VMEM((bq, 1), jnp.float32),       # running denom l
            pltpu.VMEM((bq, hd), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qh, kh, vh)
    return out.reshape(b, hq, t, hd).transpose(0, 2, 1, 3)
