"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lowrank_linear_ref(x, b_t, a_t):
    """y = (x @ b_t) @ a_t — COALA factored linear. x: (..., d_in)."""
    return (x @ b_t) @ a_t


def gram_accum_ref(chunks):
    """G = Σ_c cᵀ c over token chunks (rows of Xᵀ). chunks: (p, k, n) or list."""
    g = None
    for c in chunks:
        contrib = c.T.astype(jnp.float32) @ c.astype(jnp.float32)
        g = contrib if g is None else g + contrib
    return g


def flash_attention_ref(q, k, v, *, scale=None, cap: float = 0.0,
                        causal: bool = True):
    """q: (B, T, Hq, hd), k/v: (B, T, Hkv, hd) with Hq % Hkv == 0."""
    b, t, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(b, t, hkv, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    if cap > 0:
        s = cap * jnp.tanh(s / cap)
    if causal:
        i, j = jnp.arange(t), jnp.arange(t)
        s = jnp.where(i[:, None] >= j[None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(b, t, hq, hd)
