"""Fused low-rank linear Pallas kernel:  y = (x @ Bᵀ) @ Aᵀ.

The COALA serving hot path. A dense (d_in × d_out) matmul becomes two thin
matmuls through the rank-r bottleneck; fusing them keeps the (block_m, r)
intermediate in VMEM instead of round-tripping it through HBM.

Tiling: grid (M/bm, d_out/bn). Each program computes
    t = x[i]   @ b_t      (bm, r)     — full-K MXU contraction
    y = t      @ a_t[:, j] (bm, bn)
The rank-r intermediate is recomputed once per output column block; for the
ranks COALA produces (r ≤ ~0.3·min(m,n)) the recompute is ≤ a few % of total
FLOPs and far cheaper than an HBM round trip of t.

VMEM per program (bm=256, bn=512, d_in=8192, r=512, bf16):
  x 4.0MB + b_t 8.0MB + a_t 0.5MB + out 0.25MB ≈ 12.8MB < 16MB v5e VMEM.
MXU alignment: bm, bn, r multiples of 128 (pad r if needed at the wrapper).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, bt_ref, at_ref, o_ref):
    t = jnp.dot(x_ref[...], bt_ref[...],
                preferred_element_type=jnp.float32)        # (bm, r)
    o_ref[...] = jnp.dot(t.astype(x_ref.dtype), at_ref[...],
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def lowrank_linear(x, b_t, a_t, *, block_m: int = 256, block_n: int = 512,
                   interpret: bool = False):
    """x: (..., d_in); b_t: (d_in, r); a_t: (r, d_out) -> (..., d_out)."""
    orig_shape = x.shape
    d_in = x.shape[-1]
    r, d_out = a_t.shape
    xm = x.reshape(-1, d_in)
    m = xm.shape[0]
    bm = min(block_m, m)
    bn = min(block_n, d_out)
    if m % bm or d_out % bn:            # shape fallback: unfused reference
        y = (xm @ b_t) @ a_t
        return y.reshape(*orig_shape[:-1], d_out)
    grid = (m // bm, d_out // bn)
    y = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((d_in, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d_out), x.dtype),
        interpret=interpret,
    )(xm, b_t, a_t)
    return y.reshape(*orig_shape[:-1], d_out)
