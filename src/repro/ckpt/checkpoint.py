"""Fault-tolerant checkpointing: atomic, async, keep-k, cross-mesh reshard.

Layout:   <dir>/step_<N>/manifest.json + leaf_<i>.npy
Atomicity: written to ``<dir>/.tmp_step_<N>`` then ``os.rename``d — a crash
mid-write never corrupts the latest checkpoint.
Async:    ``save(..., blocking=False)`` snapshots to host (device_get) on the
caller thread (cheap, overlapped with the next step's compute by XLA) and
writes files on a background thread — checkpoint I/O is off the critical path.
Elastic restore: leaves are stored unsharded; ``restore`` device_puts them
with whatever shardings the *new* mesh prescribes, so restarts may change
pod/data/model sizes freely (ZeRO resharding for free).

At 1000+ nodes each host would write only its addressable shards
(jax.experimental.multihost_utils / array serialization); the manifest format
already records per-leaf shape+dtype so that extension is mechanical — noted
in DESIGN.md.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.obs import trace


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, *, blocking: bool = True,
             extra_meta: Optional[Dict[str, Any]] = None):
        paths, leaves, _ = _flatten_with_paths(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        meta = {"step": int(step), "paths": paths,
                "shapes": [list(x.shape) for x in host_leaves],
                "dtypes": [str(x.dtype) for x in host_leaves]}
        if extra_meta:
            meta.update(extra_meta)

        def write():
            # the tracer is thread-safe: an async save records this span
            # from the background thread (its own tid lane in the trace)
            with trace.span("ckpt.save", step=int(step),
                            leaves=len(host_leaves),
                            blocking=bool(blocking)):
                tmp = os.path.join(self.dir, f".tmp_step_{step}")
                final = os.path.join(self.dir, f"step_{step}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                for i, arr in enumerate(host_leaves):
                    np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(meta, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._prune()

        self.wait()                      # one in-flight async save at a time
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_", 1)[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``tree_like``; device_put with
        ``shardings`` (same treedef) if given — this is the elastic reshard."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with trace.span("ckpt.restore", step=int(step)):
            d = os.path.join(self.dir, f"step_{step}")
            with open(os.path.join(d, "manifest.json")) as f:
                meta = json.load(f)
            paths, leaves, treedef = _flatten_with_paths(tree_like)
            assert paths == meta["paths"], "checkpoint/tree structure mismatch"
            arrays = [np.load(os.path.join(d, f"leaf_{i}.npy"))
                      for i in range(len(paths))]
            if shardings is not None:
                flat_sh = treedef.flatten_up_to(shardings)
                arrays = [jax.device_put(a, s)
                          for a, s in zip(arrays, flat_sh)]
            else:
                arrays = [jax.numpy.asarray(a) for a in arrays]
        return treedef.unflatten(arrays), meta
