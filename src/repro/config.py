"""Central configuration dataclasses for the repro framework.

Everything is a frozen dataclass so configs are hashable and usable as
static args under jit. Architecture configs live in ``repro.configs``;
this module defines the schema.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for one FFN layer family."""
    num_experts: int = 0              # routed experts (0 = dense FFN)
    top_k: int = 0
    num_shared: int = 0               # always-on shared experts
    d_ff_expert: int = 0              # per-expert hidden dim
    capacity_factor: float = 1.25
    min_capacity: int = 4
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                  # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8              # 1 sLSTM block per this many layers
    proj_factor: float = 2.0          # mLSTM up-projection factor
    chunk_size: int = 64              # chunked parallel mLSTM scan


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture. ``family`` selects the block wiring."""
    name: str = "unnamed"
    family: str = "dense"             # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 64
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 0                 # 0 -> d_model // n_heads
    d_ff: int = 128
    vocab_size: int = 256
    max_seq_len: int = 8192
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    # --- family-specific knobs -------------------------------------------
    moe: MoEConfig = MoEConfig()
    mamba: MambaConfig = MambaConfig()
    xlstm: XLSTMConfig = XLSTMConfig()

    # gemma2-style
    local_window: int = 0             # 0 = all-global; else alternate local/global
    query_scale: float = 0.0          # 0 -> 1/sqrt(head_dim)
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    post_block_norm: bool = False     # sandwich norms (gemma2)

    # olmo: non-parametric LayerNorm
    nonparametric_norm: bool = False

    # minicpm mup-ish scaling
    scale_emb: float = 1.0
    scale_depth: float = 0.0          # 0 = off; else residual scaled by scale_depth/sqrt(L)
    dim_model_base: int = 0           # 0 = off; logits scaled by d_model/dim_model_base

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0             # 0 = plain GQA
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # MoE layer pattern: layer i uses MoE if i >= first_dense and pattern hit
    moe_every: int = 1                # MoE FFN if (i % moe_every == moe_offset)
    moe_offset: int = 0
    first_k_dense: int = 0            # first k layers use dense FFN (deepseek)

    # hybrid (jamba): attention layer if i % attn_every == attn_offset, else mamba
    attn_every: int = 0               # 0 = all-attention
    attn_offset: int = 0

    # enc-dec (whisper)
    n_enc_layers: int = 0
    n_audio_frames: int = 1500        # stub frontend sequence length

    # vlm (qwen2-vl)
    n_vision_tokens: int = 0          # prefix of precomputed patch embeds
    mrope_sections: Tuple[int, int, int] = (0, 0, 0)  # M-RoPE t/h/w splits

    # ffn activation: "silu" | "gelu" | "gelu_tanh"
    act: str = "silu"
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def uses_moe(self) -> bool:
        return self.moe.num_experts > 0

    def layer_kind(self, i: int) -> str:
        """'attn' | 'mamba' | 'slstm' | 'mlstm' for decoder layer i."""
        if self.family == "ssm":
            if self.xlstm.slstm_every and i % self.xlstm.slstm_every == 0:
                return "slstm"
            return "mlstm"
        if self.attn_every:
            return "attn" if i % self.attn_every == self.attn_offset else "mamba"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if not self.uses_moe:
            return False
        if i < self.first_k_dense:
            return False
        return i % self.moe_every == self.moe_offset

    def layer_is_local_attn(self, i: int) -> bool:
        """gemma2 alternation: even layers local, odd global."""
        return self.local_window > 0 and (i % 2 == 0)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""
    name: str = "train_4k"
    seq_len: int = 4096
    global_batch: int = 256
    kind: str = "train"               # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    pod: int = 1
    data: int = 16
    model: int = 16

    @property
    def num_devices(self) -> int:
        return self.pod * self.data * self.model


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"          # cosine | wsd | const
    warmup_steps: int = 100
    total_steps: int = 1000
    decay_frac: float = 0.1           # WSD decay fraction
    microbatches: int = 1             # grad accumulation
    remat: str = "dots"               # none | dots | full
    grad_compress_pods: bool = False  # int8 error-feedback cross-pod reduce
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    """COALA / baselines model compression settings."""
    method: str = "coala"             # coala | svd_llm | svd_llm_v2 | asvd | svd
    ratio: float = 0.7                # kept parameter fraction of compressed layers
    lam: float = 4.0                  # λ in Eq.(5) (paper: stable in [1,10])
    mu: float = -1.0                  # explicit μ; -1 = per-layer Eq.(5)
    alpha: float = 1.0                # Prop.4 weighting exponent (adapters)
    rank: int = 0                     # explicit rank overrides ratio when >0
    use_rsvd: bool = False            # beyond-paper randomized SVD path
    rsvd_oversample: int = 8
    rsvd_power_iters: int = 2
    adaptive_rank: bool = False       # water-filling per-layer ranks (beyond-paper)
    chunk_tokens: int = 4096          # TSQR streaming chunk size
    calib_dtype: str = "float32"
