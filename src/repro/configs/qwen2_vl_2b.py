"""qwen2-vl-2b [vlm]: M-RoPE, dynamic resolution (vision frontend stubbed).

[arXiv:2409.12191; hf] 28L d_model=1536 12H (kv=2) d_ff=8960 vocab=151936.
Inputs: precomputed patch embeddings (B, n_vision_tokens, d_model) prefix +
text tokens. M-RoPE sections (t,h,w) = (16,24,24) over head_dim//2 = 64.
"""
import dataclasses
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab_size=151936, max_seq_len=32768,
    n_vision_tokens=256, mrope_sections=(16, 24, 24),
    rope_theta=1e6, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, max_seq_len=256, n_vision_tokens=8,
    mrope_sections=(4, 2, 2))
