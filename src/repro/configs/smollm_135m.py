"""smollm-135m [dense]: llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]

30L d_model=576 9H (kv=3) d_ff=1536 vocab=49152.
"""
import dataclasses
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536,
    vocab_size=49152, max_seq_len=32768, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, d_ff=96,
    vocab_size=256, max_seq_len=256)
