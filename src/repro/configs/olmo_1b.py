"""olmo-1b [dense]: non-parametric LayerNorm, SwiGLU, RoPE, weight tying.

[arXiv:2402.00838; hf] 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304.
"""
import dataclasses
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=50304, max_seq_len=32768,
    nonparametric_norm=True, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, max_seq_len=256)
