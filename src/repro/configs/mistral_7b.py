"""mistral-7b: the paper's Table 3 compression target.

32L d_model=4096 32H (kv=8) d_ff=14336 vocab=32000.
"""
import dataclasses
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, max_seq_len=32768, rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, max_seq_len=256)
