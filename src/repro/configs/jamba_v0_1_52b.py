"""jamba-v0.1-52b [hybrid]: Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf] 32L d_model=4096 32H (kv=8) d_ff=14336 vocab=65536.
Layer i is attention iff i % 8 == 4 (one per Jamba block of 8); MoE replaces
the MLP on every other layer (i % 2 == 1), 16 experts top-2, no shared.
Mamba: d_state=16, d_conv=4, expand=2, dt_rank=256.
"""
import dataclasses
from repro.config import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536, max_seq_len=524288,
    attn_every=8, attn_offset=4,
    moe=MoEConfig(num_experts=16, top_k=2, num_shared=0, d_ff_expert=14336),
    moe_every=2, moe_offset=1,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, max_seq_len=256, attn_every=4, attn_offset=2,
    moe=MoEConfig(num_experts=4, top_k=2, num_shared=0, d_ff_expert=32,
                  min_capacity=2),
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2, dt_rank=16))
