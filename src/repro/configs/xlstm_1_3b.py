"""xlstm-1.3b [ssm]: mixed sLSTM + mLSTM blocks (1 sLSTM per 8 layers).

[arXiv:2405.04517; unverified] 48L d_model=2048 4H d_ff=0 vocab=50304.
Blocks carry their own gated projections (d_ff=0 per assignment).
"""
import dataclasses
from repro.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, max_seq_len=524288,
    xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0),
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=8, d_model=64, n_heads=2, n_kv_heads=2, vocab_size=256,
    max_seq_len=256, xlstm=XLSTMConfig(slstm_every=4, proj_factor=2.0))
