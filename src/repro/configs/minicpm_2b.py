"""minicpm-2b [dense]: llama-like with mup-style scaling + WSD schedule.

[arXiv:2404.06395; hf] 40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753.
scale_emb=12, scale_depth=1.4, dim_model_base=256 per the paper; the WSD
learning-rate schedule lives in train/optimizer.py (schedule="wsd").
"""
import dataclasses
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab_size=122753, max_seq_len=32768,
    scale_emb=12.0, scale_depth=1.4, dim_model_base=256,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, max_seq_len=256)
