"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512) + 2 shared + 64 routed top-6.

[arXiv:2405.04434; hf] 27L d_model=2048 16H d_ff_expert=1408 vocab=102400.
Assignment line says both "MoE 64e" and "160 routed"; HF config is 64 routed
(2 shared, top-6) — we follow 64e (see DESIGN.md §7).
MLA dims per HF: q_head = 128 nope + 64 rope, v_head = 128, kv_lora_rank 512.
"""
import dataclasses
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
    head_dim=192, vocab_size=102400, max_seq_len=524288,
    kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_ff_expert=1408),
    first_k_dense=1,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    head_dim=48, kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16,
    v_head_dim=32, vocab_size=256, max_seq_len=256,
    moe=MoEConfig(num_experts=8, top_k=2, num_shared=1, d_ff_expert=32,
                  min_capacity=2))
