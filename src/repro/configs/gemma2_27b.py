"""gemma2-27b [dense]: local(4096)/global alternating attention, softcaps.

[arXiv:2408.00118; hf] 46L d_model=4608 32H (kv=16) d_ff=36864 vocab=256000.
head_dim=128 (q/k/v project 4608->4096); query scale (d_model/n_heads)^-0.5;
attn softcap 50, final softcap 30; sandwich (post-block) RMSNorms; GeGLU.
"""
import dataclasses
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000, max_seq_len=524288,
    local_window=4096, query_scale=(4608 / 32) ** -0.5,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_block_norm=True, scale_emb=4608 ** 0.5,
    act="gelu_tanh", tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, max_seq_len=256, local_window=32,
    query_scale=(64 / 4) ** -0.5, scale_emb=8.0)
