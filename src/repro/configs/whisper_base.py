"""whisper-base [audio]: enc-dec, conv frontend stubbed (precomputed frames).

[arXiv:2212.04356; unverified] 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.
"""
import dataclasses
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865, max_seq_len=32768, n_audio_frames=1500,
    act="gelu", tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, max_seq_len=256, n_audio_frames=32)
