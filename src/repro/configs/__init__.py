"""Assigned architecture registry: ``get_config(name)`` / ``get_smoke_config``.

Each module defines CONFIG (the exact assigned full-size config) and
SMOKE (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig

ARCH_IDS: List[str] = [
    "whisper_base",
    "deepseek_moe_16b",
    "deepseek_v2_lite_16b",
    "xlstm_1_3b",
    "gemma2_27b",
    "olmo_1b",
    "smollm_135m",
    "minicpm_2b",
    "qwen2_vl_2b",
    "jamba_v0_1_52b",
    # the paper's own evaluation models (compression targets)
    "llama3_1b",
    "mistral_7b",
]


def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
