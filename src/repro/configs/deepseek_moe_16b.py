"""deepseek-moe-16b [moe]: 2 shared + 64 routed top-6, fine-grained experts.

[arXiv:2401.06066; hf] 28L d_model=2048 16H (kv=16) d_ff_expert=1408
vocab=102400; first layer dense FFN (d_ff=10944).
"""
import dataclasses
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
    vocab_size=102400, max_seq_len=524288,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_ff_expert=1408),
    first_k_dense=1,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, max_seq_len=256,
    moe=MoEConfig(num_experts=8, top_k=2, num_shared=1, d_ff_expert=32,
                  min_capacity=2))
