"""llama3-1b: the paper's own compression/fine-tuning target (LLaMA3.2-1B).

16L d_model=2048 32H (kv=8) d_ff=8192 vocab=128256.
"""
import dataclasses
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab_size=128256, max_seq_len=32768, rope_theta=5e5,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, max_seq_len=256)
