"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = Σ link_bytes_per_device(op) / ICI_BW

``cost_analysis()`` on the SPMD-partitioned module is per-device (verified
against a hand-checked matmul). Collective link bytes use ring-algorithm
costs parsed from the compiled HLO text:

  all-reduce:         2·(s-1)/s · result_bytes
  all-gather:           (s-1)/s · result_bytes        (result = gathered)
  reduce-scatter:       (s-1)   · result_bytes        (input = s · result)
  all-to-all:           (s-1)/s · result_bytes
  collective-permute:             result_bytes

where s = replica-group size parsed from the op. Hardware: TPU v5e-like —
197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI per chip.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<shapes>.*?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start|-done)?\(", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_WHILE_RE = re.compile(
    r"while\([^)]*\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUP_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _split_computations(hlo_text: str) -> Dict[str, list]:
    """computation name -> list of body lines. Entry computation keyed as
    '__entry__' too."""
    comps: Dict[str, list] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    entry = cur
        else:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _trip_count(cond_lines: list) -> int:
    """Trip count of a scan-style while: the max s32 scalar constant in the
    condition computation (induction starts at 0, compares LT bound)."""
    consts = []
    for line in cond_lines:
        consts += [int(c) for c in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: count, result bytes, effective link bytes.

    Walks the computation graph hierarchically and multiplies collectives
    inside ``while`` bodies (lax.scan) by the loop trip count — XLA's flat
    text would otherwise count per-layer collectives once.
    """
    comps = _split_computations(hlo_text)
    out: Dict[str, Dict[str, float]] = {}

    def visit(comp_name: str, mult: float, seen):
        if comp_name not in comps or comp_name in seen:
            return
        seen = seen | {comp_name}
        for line in comps[comp_name]:
            m = _COLL_RE.match(line)
            if m and m.group("start") != "-done":
                op = m.group("op")
                rb = _shape_bytes(m.group("shapes"))
                s = _group_size(line)
                if op == "all-reduce":
                    link = 2.0 * (s - 1) / s * rb
                elif op == "all-gather":
                    link = (s - 1) / s * rb
                elif op == "reduce-scatter":
                    link = float(s - 1) * rb
                elif op == "all-to-all":
                    link = (s - 1) / s * rb
                else:  # collective-permute
                    link = float(rb)
                d = out.setdefault(op, {"count": 0, "result_bytes": 0.0,
                                        "link_bytes": 0.0})
                d["count"] += mult
                d["result_bytes"] += mult * rb
                d["link_bytes"] += mult * link
            w = _WHILE_RE.search(line)
            if w:
                cond, body = w.group(1), w.group(2)
                trips = _trip_count(comps.get(cond, []))
                visit(body, mult * trips, seen)

    visit("__entry__", 1.0, frozenset())
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_dev: float
    bytes_per_dev: float
    collective_link_bytes: float
    collectives: Dict[str, Dict[str, float]]
    model_flops_global: float
    n_devices: int
    memory_stats: Dict[str, float]

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_link_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops — fraction of compiled compute
        that is 'useful' model math (catches remat/redundancy waste)."""
        total = self.flops_per_dev * self.n_devices
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOP utilization at the bound: (model flops / peak) over
        the dominant term's time — the score we hillclimb."""
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        if t_bound <= 0:
            return 0.0
        t_useful = (self.model_flops_global / self.n_devices) / PEAK_FLOPS
        return t_useful / t_bound

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, dominant=self.dominant,
                 useful_ratio=self.useful_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def analyze(compiled, *, arch: str, shape: str, mesh_name: str,
            n_devices: int, model_flops_global: float,
            jaxpr_cost: Optional[Dict[str, float]] = None) -> Roofline:
    """``jaxpr_cost`` (from roofline.jaxpr_cost.trace_cost) supplies
    scan-aware global flops/bytes; XLA's cost_analysis counts while bodies
    once and is kept only as a diagnostic."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):      # pinned jax: one dict per program
        ca = ca[0] if ca else {}
    hlo_flops_once = float(ca.get("flops", 0.0))
    hlo_bytes_once = float(ca.get("bytes accessed", 0.0))
    if jaxpr_cost is not None:
        flops = float(jaxpr_cost["flops"]) / n_devices
        byts = float(jaxpr_cost["bytes"]) / n_devices
    else:
        flops, byts = hlo_flops_once, hlo_bytes_once
    # NB: the SPMD module is per-device, so collective shapes (and hence link
    # bytes) are already per-device quantities — no division by n_devices.
    colls = parse_collectives(compiled.as_text())
    link_bytes = sum(v["link_bytes"] for v in colls.values())
    try:
        ms = compiled.memory_analysis()
        mem = {"argument_bytes": ms.argument_size_in_bytes,
               "output_bytes": ms.output_size_in_bytes,
               "temp_bytes": ms.temp_size_in_bytes,
               "alias_bytes": ms.alias_size_in_bytes,
               "code_bytes": ms.generated_code_size_in_bytes}
    except Exception:
        mem = {}
    return Roofline(arch=arch, shape=shape, mesh=mesh_name,
                    flops_per_dev=flops, bytes_per_dev=byts,
                    collective_link_bytes=link_bytes, collectives=colls,
                    model_flops_global=model_flops_global,
                    n_devices=n_devices, memory_stats=mem)


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6·N·D for train, 2·N·D for inference; MoE uses active params)
# ---------------------------------------------------------------------------

def count_params(tree) -> int:
    import jax
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def active_params(cfg, params_tree) -> float:
    """Total params minus the inactive routed-expert fraction."""
    import jax
    total = count_params(params_tree)
    if not cfg.uses_moe:
        return float(total)
    routed = 0
    flat = jax.tree_util.tree_flatten_with_path(params_tree)[0]
    for path, leaf in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        if any(k in ("w_gate", "w_up", "w_down") for k in keys):
            routed += int(leaf.size)
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    return float(total - routed * (1.0 - k / e))


def model_flops(cfg, params_tree, shape_cfg) -> float:
    n_act = active_params(cfg, params_tree)
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * n_act * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * shape_cfg.global_batch
