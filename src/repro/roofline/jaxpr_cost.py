"""Scan-aware algorithmic FLOP/byte counter over jaxprs.

XLA's ``cost_analysis()`` counts a ``while`` body ONCE, so anything under
``lax.scan`` (layer stacks, microbatching, chunked attention, recurrent
cells) is undercounted by its trip count. At the jaxpr level every scan
length is static, so this walker computes exact algorithmic totals:

  * flops — 2·M·N·K per dot_general (batch-aware), plus 1 flop/output
    element for elementwise work (softmax/exp/mask visible but not dominant)
  * bytes — Σ (operand + result) sizes per equation: an UNFUSED upper bound
    on HBM traffic. Real hardware fuses aggressively, so treat absolute
    values as pessimistic and deltas as meaningful.

Scan bodies multiply by ``length``; remat/checkpoint regions are counted as
traced (so backward recompute shows up — that is the point); shard_map
bodies (local shapes) multiply by the mesh device count to give global
totals. Divide by n_devices for the per-device roofline terms (assumes SPMD
balance; replicated-compute layers are flagged separately in the report).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import numpy as np
from jax import core as jcore


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 0


def _bytes(aval) -> int:
    try:
        return _size(aval) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> float:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    batch = 1
    for d in lb:
        batch *= a.shape[d]
    k = 1
    for d in lc:
        k *= a.shape[d]
    m = _size(a) // max(1, batch * k)
    n = _size(b) // max(1, batch * k)
    return 2.0 * batch * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops ≈ 2 × output elements × (kernel spatial × in-channels)
    k = _size(rhs) // max(1, rhs.shape[eqn.params[
        "dimension_numbers"].rhs_spec[0]])
    return 2.0 * _size(out) * k


_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr",
                    "body_jaxpr")


def _sub_jaxprs(eqn):
    for name in _SUBJAXPR_PARAMS:
        if name in eqn.params:
            sub = eqn.params[name]
            yield name, sub
    if "branches" in eqn.params:
        for br in eqn.params["branches"]:
            yield "branch", br


def count(closed_jaxpr) -> Dict[str, float]:
    """Returns {'flops': global algorithmic flops, 'bytes': unfused bytes}."""
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    flops = 0.0
    byts = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        mult = 1.0
        if prim == "scan":
            mult = float(eqn.params.get("length", 1))
        elif prim == "while":
            mult = 1.0  # unknown trips; we do not emit raw whiles
        elif prim == "shard_map":
            mesh = eqn.params.get("mesh")
            try:
                mult = float(mesh.size)
            except Exception:
                mult = 1.0

        subs = list(_sub_jaxprs(eqn))
        if subs:
            inner_f = inner_b = 0.0
            if prim == "cond":
                branch_costs = [count(s) for _, s in subs if _ == "branch"] \
                    or [count(s) for _, s in subs]
                best = max(branch_costs, key=lambda c: c["flops"])
                inner_f, inner_b = best["flops"], best["bytes"]
            else:
                for _, s in subs:
                    c = count(s)
                    inner_f += c["flops"]
                    inner_b += c["bytes"]
                    if prim in ("scan", "while", "shard_map", "pjit",
                                "remat2", "checkpoint", "custom_vjp_call",
                                "custom_jvp_call", "custom_vjp_call_jaxpr"):
                        break  # these carry ONE body jaxpr; avoid dup count
            flops += mult * inner_f
            byts += mult * inner_b
            continue

        if prim == "dot_general":
            flops += mult * _dot_flops(eqn)
        elif prim == "conv_general_dilated":
            flops += mult * _conv_flops(eqn)
        else:
            flops += mult * sum(_size(v.aval) for v in eqn.outvars)
        byts += mult * (sum(_bytes(v.aval) for v in eqn.invars
                            if hasattr(v, "aval"))
                        + sum(_bytes(v.aval) for v in eqn.outvars))
    return {"flops": flops, "bytes": byts}


def trace_cost(fn, *args, **kwargs) -> Dict[str, float]:
    cj = jax.make_jaxpr(fn)(*args, **kwargs)
    return count(cj)
