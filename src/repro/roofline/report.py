"""Render the §Dry-run / §Roofline tables from experiments/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load_results(out_dir: str = "experiments/dryrun") -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        parts = os.path.basename(path)[:-5].split("__")
        r["tag"] = parts[3] if len(parts) > 3 else ""
        out.append(r)
    return out


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def roofline_table(results: List[dict], mesh: str = "single",
                   tag_filter=None) -> str:
    rows = []
    header = ("| arch | shape | t_compute | t_memory | t_collective | dominant "
              "| useful | roofline frac | per-dev mem |")
    sep = "|" + "---|" * 9
    for r in results:
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        if r.get("tag") and not tag_filter:
            continue                      # hillclimb variants listed separately
        tag = f" [{r['tag']}]" if r.get("tag") else ""

        mem = r.get("memory_stats", {})
        dev_mem = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0))
        rows.append(
            f"| {r['arch']} | {r['shape']}{tag} | {r['t_compute']:.4f}s "
            f"| {r['t_memory']:.4f}s | {r['t_collective']:.4f}s "
            f"| **{r['dominant']}** | {r['useful_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} | {_fmt_bytes(dev_mem)} |")
    return "\n".join([header, sep] + rows)


def dryrun_table(results: List[dict]) -> str:
    header = ("| arch | shape | mesh | status | compile s | per-dev FLOPs "
              "| per-dev bytes | collective link bytes | collectives |")
    sep = "|" + "---|" * 9
    rows = []
    for r in results:
        if r.get("tag"):
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r.get('arch')} | {r.get('shape')} "
                        f"| {r.get('mesh')} | ERROR | | | | | |")
            continue
        colls = ", ".join(f"{k}×{int(v['count'])}"
                          for k, v in sorted(r.get("collectives", {}).items()))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r.get('t_compile_s', 0):.0f} | {r['flops_per_dev']:.3g} "
            f"| {_fmt_bytes(r['bytes_per_dev'])} "
            f"| {_fmt_bytes(r['collective_link_bytes'])} | {colls} |")
    return "\n".join([header, sep] + rows)


def pick_hillclimb_cells(results: List[dict]) -> Dict[str, dict]:
    """worst roofline fraction (among train), most collective-bound, and the
    paper-representative compressed-serving cell."""
    ok = [r for r in results if r.get("status") == "ok"
          and r.get("mesh") == "single" and not r.get("tag")]
    train = [r for r in ok if r["shape"] == "train_4k"]
    worst = min(train, key=lambda r: r["roofline_fraction"])
    coll = max(ok, key=lambda r: r["t_collective"] /
               max(r["t_compute"] + r["t_memory"], 1e-12))
    return {"worst_fraction": worst, "most_collective": coll}


if __name__ == "__main__":
    res = load_results()
    print("## Dry-run results\n")
    print(dryrun_table(res))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(res, "single"))
    print("\n## Hillclimb variants (tagged)\n")
    print(roofline_table([r for r in res if r.get("tag")], "single",
                         tag_filter=True))
    picks = pick_hillclimb_cells(res)
    print("\nhillclimb candidates:")
    for k, r in picks.items():
        print(f"  {k}: {r['arch']} × {r['shape']} "
              f"(frac={r['roofline_fraction']:.3f}, dom={r['dominant']})")
