"""Production training launcher.

On the CPU container this runs reduced configs end-to-end; on a real fleet
the same script runs under `jax.distributed` (one process per host — set
--coordinator for multi-host initialization).

Fault-tolerance model (synchronous SPMD at 1000+ nodes):
  * atomic async checkpoints every --ckpt-every steps (tmp+rename; a crash
    mid-write never corrupts the restore target);
  * on ANY failure the job scheduler restarts this launcher; it resumes from
    the latest checkpoint, and the step-indexed data pipeline replays the
    exact token stream — no state beyond the step counter;
  * elastic restarts: the checkpoint stores unsharded leaves, restore
    device_puts them under the NEW mesh's shardings — pod/data/model sizes
    may change between runs (ZeRO resharding for free);
  * stragglers: synchronous SPMD makes the step time the max over chips. The
    deployment recipe is (a) checkpoint-restart onto a hot-spare pod when a
    chip degrades (swap the failed pod's slice address, resume), (b) the
    cross-pod gradient hop is int8-compressed (--grad-compress) so slow DCN
    links stop dominating, (c) XLA's latency-hiding scheduler overlaps the
    FSDP all-gathers with compute (enabled via flags below).

  PYTHONPATH=src python -m repro.launch.train --arch smollm_135m --smoke \
      --steps 50 --mesh 1,1,1
"""
import os

# latency-hiding scheduler: overlap collectives with compute on real hw
os.environ.setdefault(
    "XLA_FLAGS",
    os.environ.get("XLA_FLAGS", "") +
    " --xla_tpu_enable_latency_hiding_scheduler=true"
    if os.environ.get("JAX_PLATFORMS", "") == "tpu" else
    os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.ckpt import CheckpointManager  # noqa: E402
from repro.config import TrainConfig  # noqa: E402
from repro.configs import get_config, get_smoke_config  # noqa: E402
from repro.data import DataConfig, TokenPipeline  # noqa: E402
from repro.dist.sharding import (batch_axes_of, batch_specs,  # noqa: E402
                                 to_named, train_state_specs)
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.models.common import CPU_CTX, ParallelCtx  # noqa: E402
from repro.train import grad_compress as gc  # noqa: E402
from repro.train.train_loop import (make_train_state,  # noqa: E402
                                    make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default="1,1,1",
                    help="pod,data,model sizes (1,1,1 = single device)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "const"])
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--grad-compress", action="store_true",
                    help="int8+EF cross-pod gradient reduction")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--coordinator", default="",
                    help="host:port for jax.distributed multi-host init")
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--num-processes", type=int, default=1)
    args = ap.parse_args()

    if args.coordinator:
        jax.distributed.initialize(args.coordinator, args.num_processes,
                                   args.process_id)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    tcfg = TrainConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                       total_steps=args.steps, schedule=args.schedule,
                       microbatches=args.microbatches, remat=args.remat,
                       grad_compress_pods=args.grad_compress,
                       compute_dtype="float32" if args.smoke else "bfloat16")

    shape = tuple(int(x) for x in args.mesh.split(","))
    multi = shape[0] * shape[1] * shape[2] > 1
    if multi:
        mesh = make_mesh(shape, ("pod", "data", "model"))
        ctx = ParallelCtx(mesh=mesh, batch_axes=batch_axes_of(mesh),
                          shard_map_moe=cfg.uses_moe)
    else:
        mesh, ctx = None, CPU_CTX

    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch), cfg)
    state = make_train_state(model, tcfg, jax.random.PRNGKey(tcfg.seed))
    if tcfg.grad_compress_pods and multi:
        state["err"] = gc.init_error_state(state["params"], shape[0])

    step_fn = make_train_step(model, tcfg, ctx, mesh=mesh)
    if multi:
        sspecs = train_state_specs(cfg, state, mesh, strategy="fsdp")
        bspecs = batch_specs(cfg, pipe.get_batch(0), mesh)
        step_fn = jax.jit(step_fn,
                          in_shardings=(to_named(sspecs, mesh),
                                        to_named(bspecs, mesh)),
                          # pin the output state to the same shardings so the
                          # step round-trips (XLA would otherwise pick its
                          # own layout for some leaves and poison step 2)
                          out_shardings=(to_named(sspecs, mesh), None),
                          donate_argnums=0)
        state = jax.device_put(state, to_named(sspecs, mesh))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=0)

    mgr = None
    start = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        if mgr.latest_step() is not None:
            state, meta = mgr.restore(state)
            start = meta["step"] + 1
            print(f"[resume] step {meta['step']}")

    t0 = time.time()
    for i in range(start, args.steps):
        state, metrics = step_fn(state, pipe.get_batch(i))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} ce={float(metrics['ce']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({(time.time() - t0) / max(1, i - start + 1):.2f}s/step)",
                  flush=True)
        if mgr and i > start and i % args.ckpt_every == 0:
            mgr.save(i, state, blocking=False)
    if mgr:
        mgr.wait()
        mgr.save(args.steps - 1, state)
    print("done")


if __name__ == "__main__":
    main()
