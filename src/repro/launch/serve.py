"""Serving launcher: batched generation with an optionally COALA-compressed
model (the paper's deployment target).

Fixed-batch (legacy fallback):

  PYTHONPATH=src python -m repro.launch.serve --arch smollm_135m --smoke \
      --compress-ratio 0.6 --requests 4 --new-tokens 16

Continuous batching over the paged KV cache — a mixed-length synthetic
request trace (staggered arrivals, varied prompt/output lengths) served for
both the dense and the COALA-compressed model, reporting per-request TTFT
and aggregate requests/sec:

  PYTHONPATH=src python -m repro.launch.serve --smoke --continuous
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CompressConfig
from repro.configs import get_config, get_smoke_config
from repro.core.calibrate import calibrate_model
from repro.core.compress import (compress_model, compress_model_pair,
                                 compression_summary)
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.obs import FlightRecorder, TelemetryServer
from repro.obs import trace as obs_trace
from repro.serve import ContinuousEngine, ServeEngine


def synthetic_trace(n_requests: int, vocab_size: int, *, seed: int = 0,
                    min_prompt: int = 4, max_prompt: int = 24,
                    min_new: int = 4, max_new: int = 16,
                    arrival_every: int = 2, shared_prefix: int = 0):
    """Mixed-length request trace with staggered arrivals.

    ``shared_prefix`` prepends one common random token prefix of that length
    to every prompt — the system-prompt-heavy traffic shape prefix caching
    targets. Returns a list of (arrival_step, prompt (T,), max_new_tokens)."""
    rng = np.random.RandomState(seed)
    common = rng.randint(0, vocab_size, (shared_prefix,)).astype(np.int32)
    trace = []
    for i in range(n_requests):
        t0 = int(rng.randint(min_prompt, max_prompt + 1))
        nn = int(rng.randint(min_new, max_new + 1))
        prompt = rng.randint(0, vocab_size, (t0,)).astype(np.int32)
        if shared_prefix:
            prompt = np.concatenate([common, prompt])
        trace.append((i * arrival_every, prompt, nn))
    return trace


def serve_trace(engine: ContinuousEngine, trace, *, temperature: float = 0.0):
    """Replay a trace: submissions are keyed to engine steps, so requests
    join the running decode batch mid-flight."""
    pending = list(trace)
    step = 0
    while pending or engine.has_work():
        while pending and pending[0][0] <= step:
            _, prompt, nn = pending.pop(0)
            engine.submit(prompt, nn, temperature=temperature)
        engine.step()
        step += 1
    return engine.metrics()


def _compressed_params(cfg, model, params, pipe, ratio: float,
                       draft_ratio: float = 0.0):
    """COALA-compress at ``ratio``; with ``draft_ratio`` also build the
    harder-compressed speculative draft from the same calibration pass.
    Returns ``(params, draft_params, reports, draft_reports)`` — the
    reports carry the per-layer ranks live recalibration pins its
    shape-stable rank maps from."""
    cal = calibrate_model(model, params, [pipe.get_batch(i) for i in range(2)])
    ccfg = CompressConfig(method="coala", ratio=ratio, lam=4.0, mu=-1.0)
    if draft_ratio > 0:
        cparams, dparams, reports, dreports = compress_model_pair(
            model, params, cal, ccfg, draft_ratio=draft_ratio)
        print("compression:", compression_summary(reports))
        print("draft compression:", compression_summary(dreports))
        return cparams, dparams, reports, dreports
    cparams, reports = compress_model(model, params, cal, ccfg)
    print("compression:", compression_summary(reports))
    return cparams, None, reports, None


def _parse_buckets(spec: str):
    """'1,2,4,8' -> (1, 2, 4, 8); empty -> None (engine default)."""
    return tuple(int(s) for s in spec.split(",") if s.strip()) or None


def run_continuous(args, cfg, model, params, pipe):
    if args.requests <= 0:
        print("no requests to serve")
        return None
    # live telemetry plane (docs/observability.md): the HTTP server comes
    # up before compression/warmup so scrapes work for the whole run; one
    # server spans both engines via attach(), and one flight recorder
    # accumulates lifecycle events across them
    server = None
    if args.telemetry_port >= 0:
        server = TelemetryServer(port=args.telemetry_port)
        print(f"telemetry: listening on http://{server.host}:{server.port} "
              "(/metrics /healthz /requests /snapshot)")
    flight = (FlightRecorder(capacity=args.flight_recorder)
              if args.flight_recorder > 0 else None)
    slo_ttft = args.slo_ttft_ms / 1e3 if args.slo_ttft_ms > 0 else None
    slo_tpot = args.slo_tpot_ms / 1e3 if args.slo_tpot_ms > 0 else None
    try:
        return _run_continuous(args, cfg, model, params, pipe,
                               server=server, flight=flight,
                               slo_ttft=slo_ttft, slo_tpot=slo_tpot)
    finally:
        if server is not None:
            server.close()


def _run_continuous(args, cfg, model, params, pipe, *, server, flight,
                    slo_ttft, slo_tpot):
    ratio = args.compress_ratio if args.compress_ratio > 0 else 0.6
    cparams, dparams, reports, dreports = _compressed_params(
        cfg, model, params, pipe, ratio, draft_ratio=args.draft_ratio)
    trace = synthetic_trace(args.requests, cfg.vocab_size, seed=args.seed,
                            max_new=args.new_tokens,
                            shared_prefix=args.shared_prefix)
    tristate = {"auto": None, "on": True, "off": False}
    paged = tristate[args.paged_kernel]
    prefix = tristate[args.prefix_cache]
    prefill = tristate[args.prefill_kernel]
    # warm for exactly the worst per-request cache need this trace can hit
    warm_len = max(len(p) + nn for _, p, nn in trace)
    eng = None
    for name, p in (("dense", params), ("coala", cparams)):
        eng = ContinuousEngine(model, p, compute_dtype=jnp.float32,
                               cache_dtype=jnp.float32,
                               block_size=args.block_size,
                               num_blocks=args.num_blocks,
                               max_running=args.max_running,
                               paged_kernel=paged,
                               prefill_kernel=prefill,
                               bucket_sizes=_parse_buckets(args.bucket_sizes),
                               prefix_cache=prefix,
                               prefill_bucket_sizes=_parse_buckets(
                                   args.prefill_bucket_sizes),
                               async_detok=args.detok_async == "on",
                               draft_params=dparams, spec_k=args.spec_k,
                               slo_ttft_s=slo_ttft, slo_tpot_s=slo_tpot,
                               flight_recorder=flight)
        if server is not None:
            server.attach(eng)
        worker = None
        if args.calibrate_from_traffic and name == "coala":
            # stream this engine's own traffic back into calibration and
            # hot-swap refreshed factors once the error bound clears; the
            # dense engine serves unmodified, as the parity reference
            from repro.core.compress import rank_map_from_reports
            from repro.serve import (RecalibPolicy, RecalibWorker,
                                     TrafficCalibrator)
            policy = RecalibPolicy(
                sample_rate=args.recalib_sample_rate,
                min_token_factor=args.recalib_min_token_factor,
                max_residual_excess=args.recalib_max_residual_excess,
                check_every=args.recalib_check_every)
            tcal = TrafficCalibrator(model, policy=policy, seed=args.seed)
            ccfg = CompressConfig(method="coala", ratio=ratio, lam=4.0,
                                  mu=-1.0)
            worker = RecalibWorker(
                model, params, tcal, ccfg,
                rank_map=rank_map_from_reports(reports),
                draft_ratio=args.draft_ratio,
                draft_rank_map=rank_map_from_reports(dreports)
                if dreports else None,
                async_solve=args.recalib_async == "on")
            eng.attach_recalibrator(worker)
        if args.warmup == "on":
            w = eng.warmup(max_len=warm_len)
            print(f"[{name}] warmup: {w['warmup_seconds']:.2f}s for "
                  f"{int(w['decode_signatures'])} decode + "
                  f"{int(w['prefill_signatures'])} prefill signatures "
                  f"(max_len {int(w['max_len'])})")
        if args.offline:
            reqs = [dict(prompt_tokens=prompt, max_new_tokens=nn,
                         temperature=args.temperature)
                    for _, prompt, nn in trace]
            eng.run_offline(reqs)
            m = eng.metrics()
        else:
            m = serve_trace(eng, trace, temperature=args.temperature)
        path = "paged-kernel" if eng.paged_kernel else "gather"
        mode = "offline" if args.offline else "online"
        print(f"[{name}] per-request TTFT (s):")
        for r in sorted(eng.finished, key=lambda r: r.req_id):
            print(f"  req {r.req_id:3d}: prompt={len(r.prompt):3d} "
                  f"new={len(r.out_tokens):3d} ttft={r.ttft:.3f}s"
                  + (f" (preempted x{r.preemptions})" if r.preemptions else ""))
        print(f"[{name}] aggregate ({path}, {mode}): {m['requests']} requests, "
              f"{m['requests_per_sec']:.2f} req/s, "
              f"{m['tokens_per_sec']:.1f} new tok/s "
              f"({m['decode_tok_per_s']:.1f} decode tok/s steady-state), "
              f"mean TTFT {m['mean_ttft_s']:.3f}s, "
              f"{m['decode_compiles']} decode compiles over "
              f"{m['decode_steps']} steps ({m['decode_shapes']} shape buckets)"
              + (f"; {m['post_warmup_compiles']} post-warmup compiles"
                 if args.warmup == "on" else ""))
        if slo_ttft is not None or slo_tpot is not None:
            print(f"[{name}] SLO goodput {m['slo_goodput']:.2f} "
                  f"(ttft <= {slo_ttft if slo_ttft is not None else '-'}s, "
                  f"tpot <= {slo_tpot if slo_tpot is not None else '-'}s)")
        if "spec_accept_rate" in m:
            print(f"[{name}] speculative (draft ratio {args.draft_ratio}, "
                  f"k={int(m['spec_k'])}): {int(m['spec_rounds'])} rounds, "
                  f"accept rate {m['spec_accept_rate']:.2f} "
                  f"({int(m['spec_accepted_tokens'])}/"
                  f"{int(m['spec_proposed_tokens'])} draft tokens)")
        if worker is not None:
            s = worker.summary()
            print(f"[{name}] recalibration: {s['swaps']} hot-swaps over "
                  f"{s['solve_attempts']} solve attempts, "
                  f"{s['sampled_requests']} sampled requests / "
                  f"{s['captured_tokens']} captured tokens, "
                  f"data clearance {s['clearance']:.2f}, "
                  f"residual excess {s['residual_excess']:.2f}, "
                  f"status {s['status']}; "
                  f"{m['post_warmup_compiles']} post-warmup compiles")
        prefill_path = "chunked-kernel" if eng.prefill_kernel else "gather"
        print(f"[{name}] prefill ({prefill_path}): "
              f"{m['prefill_tok_per_s']:.1f} suffix tok/s steady-state, "
              f"{m['prefill_compiles']} compiles / "
              f"{m['prefill_batches']} batched calls "
              f"({m['prefill_shapes']} length buckets); prefix cache "
              f"{'on' if eng.prefix_cache else 'off'}: "
              f"hit rate {m['prefix_hit_rate']:.2f} "
              f"({m['prefix_hit_tokens']} tokens), "
              f"{m['cached_blocks']} cached blocks, "
              f"{m['cow_copies']} COW copies, "
              f"{m['prefix_evictions']} evictions")
    return eng


def run_fixed(args, cfg, model, params, pipe):
    if args.compress_ratio > 0:
        params, _, _, _ = _compressed_params(cfg, model, params, pipe,
                                             args.compress_ratio)
    eng = ServeEngine(model, params, compute_dtype=jnp.float32,
                      cache_dtype=jnp.float32)
    batch = pipe.get_batch(0)
    extras = {k: v for k, v in batch.items() if k != "tokens"}
    out = eng.generate(batch["tokens"], max_new_tokens=args.new_tokens,
                       extras=extras or None, temperature=args.temperature)
    print(f"served {args.requests} requests x {args.new_tokens} tokens")
    print(out[:, -args.new_tokens:])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over the paged KV cache")
    ap.add_argument("--compress-ratio", type=float, default=0.0)
    ap.add_argument("--draft-ratio", type=float, default=0.0,
                    help="self-speculative decoding: also build a harder-"
                         "compressed COALA draft at this kept-parameter "
                         "ratio from the same calibration pass, and serve "
                         "with draft-proposed tokens verified by the target "
                         "(continuous engine only; 0 = off)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per speculative round "
                         "(used with --draft-ratio)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--block-size", type=int, default=8,
                    help="paged-cache tokens per block")
    ap.add_argument("--num-blocks", type=int, default=256)
    ap.add_argument("--max-running", type=int, default=8)
    ap.add_argument("--paged-kernel", choices=("auto", "on", "off"),
                    default="auto",
                    help="decode read path: paged-attention kernel vs "
                         "gather-into-contiguous (auto: paged where the "
                         "model supports it)")
    ap.add_argument("--prefill-kernel", choices=("auto", "on", "off"),
                    default="auto",
                    help="batched suffix-prefill read path: chunked-prefill "
                         "kernel over the paged pool vs gather-into-"
                         "contiguous (auto: kernel where the model supports "
                         "it)")
    ap.add_argument("--bucket-sizes", default="",
                    help="comma-separated decode batch buckets, e.g. "
                         "'1,2,4,8' (default: powers of two up to "
                         "--max-running)")
    ap.add_argument("--prefix-cache", choices=("auto", "on", "off"),
                    default="auto",
                    help="block-granular prompt-prefix reuse over the paged "
                         "cache (auto: on for pure-attention LMs)")
    ap.add_argument("--prefill-bucket-sizes", default="",
                    help="comma-separated prompt-suffix length buckets for "
                         "batched prefill, e.g. '8,16,32' (default: powers "
                         "of two, floor 8)")
    ap.add_argument("--warmup", choices=("on", "off"), default="off",
                    help="pre-compile every reachable decode/prefill jit "
                         "signature against the trash page before serving, "
                         "so the first request's TTFT equals steady state "
                         "(bounded by the trace's worst-case cache need)")
    ap.add_argument("--offline", action="store_true",
                    help="serve the trace through the offline batch lane "
                         "(run_offline: length-sorted admission, packed "
                         "bucketed prefills) instead of staggered arrivals")
    ap.add_argument("--detok-async", choices=("on", "off"), default="on",
                    help="run detokenize + stream callbacks on the "
                         "background worker thread (off: inline on the "
                         "dispatch thread, the ordering oracle)")
    ap.add_argument("--calibrate-from-traffic", action="store_true",
                    help="stream a sampled fraction of served activations "
                         "into COALA calibration and hot-swap recompressed "
                         "factors into the live engine (no drain) once the "
                         "error bound clears; applies to the coala engine "
                         "of the continuous comparison, and to the draft "
                         "too when --draft-ratio is set")
    ap.add_argument("--recalib-sample-rate", type=float, default=1.0,
                    help="fraction of requests whose token streams feed "
                         "traffic calibration (sticky per request)")
    ap.add_argument("--recalib-min-token-factor", type=float, default=0.25,
                    help="data gate: recompress only once every target "
                         "layer has streamed at least this factor times "
                         "its feature count in calibration tokens (below "
                         "1.0 is safe: the mu-regularized solve covers the "
                         "insufficient-data regime, and the residual-vs-"
                         "bound gate still has to clear)")
    ap.add_argument("--recalib-max-residual-excess", type=float, default=2.0,
                    help="bound gate: ship recompressed factors only if "
                         "every layer's achieved residual is within this "
                         "factor of the attainable error bound")
    ap.add_argument("--recalib-check-every", type=int, default=2,
                    help="poll the recalibration gates every N engine steps")
    ap.add_argument("--recalib-async", choices=("on", "off"), default="off",
                    help="run the recompression solve on a background "
                         "thread that stages the swap for the next step "
                         "boundary (off: solve inline between steps, "
                         "deterministic)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common prefix of this many tokens to "
                         "every trace prompt (prefix-cache-heavy traffic)")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome/Perfetto trace_event JSON of the "
                         "serving spans (admission, prefill, decode, "
                         "preemption, COW) to this path")
    ap.add_argument("--metrics-out", default="",
                    help="write the last engine's metrics registry in "
                         "Prometheus text exposition format to this path")
    ap.add_argument("--trace-max-events", type=int, default=0,
                    help="cap the tracer's in-memory event list as a ring "
                         "of the most recent N events, for bounded memory "
                         "on long runs (0 = unbounded)")
    ap.add_argument("--telemetry-port", type=int, default=-1,
                    help="serve live telemetry HTTP endpoints (/metrics, "
                         "/healthz, /requests, /snapshot) from the running "
                         "continuous engines on this port (0 picks an "
                         "ephemeral port; -1 = off)")
    ap.add_argument("--flight-recorder", type=int, default=0,
                    help="record per-request lifecycle events into a ring "
                         "of this capacity and dump a postmortem bundle "
                         "(POSTMORTEM_serve.json) on engine failure paths "
                         "(0 = off)")
    ap.add_argument("--slo-ttft-ms", type=float, default=0.0,
                    help="time-to-first-token SLO in milliseconds; feeds "
                         "the serve_slo_goodput gauge (0 = unset)")
    ap.add_argument("--slo-tpot-ms", type=float, default=0.0,
                    help="per-output-token latency SLO in milliseconds "
                         "(mean after the first token); feeds the "
                         "serve_slo_goodput gauge (0 = unset)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.trace_out:
        obs_trace.enable(max_events=args.trace_max_events or None)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.prompt_len,
                                    global_batch=args.requests), cfg)
    if args.continuous:
        eng = run_continuous(args, cfg, model, params, pipe)
    else:
        run_fixed(args, cfg, model, params, pipe)
        eng = None
    if args.trace_out:
        n = obs_trace.save(args.trace_out)
        print(f"wrote {n} trace events to {args.trace_out}")
    if args.metrics_out:
        if eng is None:
            print("--metrics-out needs --continuous (registry lives on the "
                  "continuous engine); skipped")
        else:
            with open(args.metrics_out, "w") as f:
                f.write(eng.registry.prometheus())
            print(f"wrote metrics exposition to {args.metrics_out}")


if __name__ == "__main__":
    main()
