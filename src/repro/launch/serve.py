"""Serving launcher: batched generation with an optionally COALA-compressed
model (the paper's deployment target).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm_135m --smoke \
      --compress-ratio 0.6 --requests 4 --new-tokens 16
"""
import argparse

import jax
import jax.numpy as jnp

from repro.config import CompressConfig
from repro.configs import get_config, get_smoke_config
from repro.core.calibrate import calibrate_model
from repro.core.compress import compress_model, compression_summary
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--compress-ratio", type=float, default=0.0)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.prompt_len,
                                    global_batch=args.requests), cfg)

    if args.compress_ratio > 0:
        cal = calibrate_model(model, params,
                              [pipe.get_batch(i) for i in range(2)])
        params, reports = compress_model(
            model, params, cal,
            CompressConfig(method="coala", ratio=args.compress_ratio,
                           lam=4.0, mu=-1.0))
        print("compression:", compression_summary(reports))

    eng = ServeEngine(model, params, compute_dtype=jnp.float32,
                      cache_dtype=jnp.float32)
    batch = pipe.get_batch(0)
    extras = {k: v for k, v in batch.items() if k != "tokens"}
    out = eng.generate(batch["tokens"], max_new_tokens=args.new_tokens,
                       extras=extras or None, temperature=args.temperature)
    print(f"served {args.requests} requests x {args.new_tokens} tokens")
    print(out[:, -args.new_tokens:])


if __name__ == "__main__":
    main()
