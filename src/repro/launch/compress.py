"""Compression launcher: calibrate → COALA/baseline → evaluate → save.

On a mesh, calibration uses the distributed butterfly TSQR over the data
axis (core/tsqr.distributed_tsqr_r); on a single device it streams through
the RStreamer. Either way the full activation matrix X never exists.

  PYTHONPATH=src python -m repro.launch.compress --arch llama3_1b --smoke \
      --method coala --ratio 0.6 --lam 4
"""
import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CompressConfig, TrainConfig
from repro.configs import get_config, get_smoke_config
from repro.core.calibrate import calibrate_model
from repro.core.compress import compress_model, compression_summary
from repro.ckpt import CheckpointManager
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.models.common import CPU_CTX
from repro.train.train_loop import make_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--method", default="coala",
                    choices=["coala", "svd", "svd_llm", "svd_llm_v2", "asvd"])
    ap.add_argument("--ratio", type=float, default=0.6)
    ap.add_argument("--lam", type=float, default=4.0)
    ap.add_argument("--mu", type=float, default=-1.0)
    ap.add_argument("--rsvd", action="store_true")
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--pretrain-steps", type=int, default=100,
                    help="train a base model first (no public weights offline)")
    ap.add_argument("--ckpt-in", default="", help="restore base model instead")
    ap.add_argument("--ckpt-out", default="")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=8, seed=11), cfg)

    tcfg = TrainConfig(lr=3e-3, warmup_steps=5, total_steps=args.pretrain_steps,
                       schedule="cosine", compute_dtype="float32")
    state = make_train_state(model, tcfg, jax.random.PRNGKey(0))
    if args.ckpt_in:
        state, _ = CheckpointManager(args.ckpt_in).restore(state)
    else:
        step = jax.jit(make_train_step(model, tcfg, CPU_CTX))
        for i in range(args.pretrain_steps):
            state, _ = step(state, pipe.get_batch(i))
    params = state["params"]

    def eval_ce(p):
        return float(np.mean([float(model.loss(p, pipe.get_batch(1000 + i),
                                               compute_dtype=jnp.float32)[0])
                              for i in range(4)]))

    base_ce = eval_ce(params)
    cal = calibrate_model(model, params,
                          [pipe.get_batch(2000 + i)
                           for i in range(args.calib_batches)])
    ccfg = CompressConfig(method=args.method, ratio=args.ratio, lam=args.lam,
                          mu=args.mu, use_rsvd=args.rsvd)
    cparams, reports = compress_model(model, params, cal, ccfg)
    s = compression_summary(reports)
    s.update(method=args.method, base_ce=base_ce, compressed_ce=eval_ce(cparams))
    print(json.dumps(s, indent=1))
    if args.ckpt_out:
        CheckpointManager(args.ckpt_out).save(0, {"params": cparams})
        print("saved to", args.ckpt_out)


if __name__ == "__main__":
    main()
