"""Compression launcher: calibrate → COALA/baseline → evaluate → save.

With ``--mesh data=N``, calibration shards activation rows over the data
axis and reduces per-shard R factors with the distributed butterfly TSQR
(``repro.dist.calibrate``); on a single device it streams through the
RStreamer. Either way the full activation matrix X never exists.

  PYTHONPATH=src python -m repro.launch.compress --arch llama3_1b --smoke \
      --method coala --ratio 0.6 --lam 4 [--mesh data=8]
"""
import argparse
import json
import os
import sys


def _peek_mesh(argv):
    """Parse ``--mesh data=N`` from raw argv (``{}`` when absent/malformed).

    Must run before the first jax import: the fake-device count is locked at
    jax initialization, so ``main()``'s argparse is too late to raise it.
    """
    val = ""
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            val = argv[i + 1]
        elif a.startswith("--mesh="):
            val = a.split("=", 1)[1]
    out = {}
    for part in val.split(","):
        if "=" in part:
            name, _, size = part.partition("=")
            try:
                out[name.strip()] = int(size)
            except ValueError:
                pass
    return out


_MESH = _peek_mesh(sys.argv)
_MESH_DEVICES = 1
for _s in _MESH.values():
    _MESH_DEVICES *= _s
if _MESH_DEVICES > 1 and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count="
                               f"{_MESH_DEVICES}").strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.config import CompressConfig, TrainConfig  # noqa: E402
from repro.configs import get_config, get_smoke_config  # noqa: E402
from repro.core.calibrate import calibrate_model  # noqa: E402
from repro.core.compress import compress_model, compression_summary  # noqa: E402
from repro.ckpt import CheckpointManager  # noqa: E402
from repro.data import DataConfig, TokenPipeline  # noqa: E402
from repro.dist.calibrate import calibrate_sharded  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.models.common import CPU_CTX  # noqa: E402
from repro.obs import numerics, trace as obs_trace  # noqa: E402
from repro.train.train_loop import make_train_state, make_train_step  # noqa: E402

CALIB_BATCH = 8          # rows per calibration batch (the TokenPipeline below)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--method", default="coala",
                    choices=["coala", "svd", "svd_llm", "svd_llm_v2", "asvd"])
    ap.add_argument("--ratio", type=float, default=0.6)
    ap.add_argument("--lam", type=float, default=4.0)
    ap.add_argument("--mu", type=float, default=-1.0)
    ap.add_argument("--rsvd", action="store_true")
    ap.add_argument("--calib-batches", type=int, default=4)
    ap.add_argument("--pretrain-steps", type=int, default=100,
                    help="train a base model first (no public weights offline)")
    ap.add_argument("--ckpt-in", default="", help="restore base model instead")
    ap.add_argument("--ckpt-out", default="")
    ap.add_argument("--mesh", default="",
                    help="shard calibration rows, e.g. 'data=8' (fake CPU "
                         "devices are forced to match before jax init; N "
                         "must be a power of two dividing the calibration "
                         "batch)")
    ap.add_argument("--numerics-report", action="store_true",
                    help="print per-layer numerical health after "
                         "calibration: cond(R) with warn/fail grading, "
                         "insufficient-data flags, and achieved residual "
                         "vs. the attainable bound (obs/numerics.py; works "
                         "for both single-device and --mesh calibration)")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome/Perfetto trace_event JSON of the "
                         "calibration/compression spans to this path")
    args = ap.parse_args()
    if args.trace_out:
        obs_trace.enable()
    if args.mesh:
        # fail fast (before minutes of pretrain/eval): _peek_mesh swallows
        # malformed values, so a typo would silently fall back to the
        # single-device path, and bad shard counts would only crash deep
        # inside split_batch / the butterfly TSQR after the expensive phase
        if not _MESH or set(_MESH) != {"data"}:
            ap.error(f"--mesh {args.mesh!r} not understood; expected "
                     f"'data=N' (calibration shards over the data axis)")
        n_shards = _MESH["data"]
        if n_shards < 1 or n_shards & (n_shards - 1):
            ap.error(f"--mesh data={n_shards}: shard count must be a power "
                     f"of two (butterfly TSQR pairing)")
        if CALIB_BATCH % n_shards:
            ap.error(f"--mesh data={n_shards}: must divide the calibration "
                     f"batch of {CALIB_BATCH} rows")
        if len(jax.devices()) < n_shards:
            # a pre-set XLA_FLAGS device count suppresses the import-time
            # forcing — surface that now, not after pretrain/eval
            ap.error(f"--mesh data={n_shards}: only {len(jax.devices())} "
                     f"devices visible (XLA_FLAGS already set?)")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=CALIB_BATCH, seed=11), cfg)

    tcfg = TrainConfig(lr=3e-3, warmup_steps=5, total_steps=args.pretrain_steps,
                       schedule="cosine", compute_dtype="float32")
    state = make_train_state(model, tcfg, jax.random.PRNGKey(0))
    if args.ckpt_in:
        state, _ = CheckpointManager(args.ckpt_in).restore(state)
    else:
        step = jax.jit(make_train_step(model, tcfg, CPU_CTX))
        for i in range(args.pretrain_steps):
            state, _ = step(state, pipe.get_batch(i))
    params = state["params"]

    def eval_ce(p):
        return float(np.mean([float(model.loss(p, pipe.get_batch(1000 + i),
                                               compute_dtype=jnp.float32)[0])
                              for i in range(4)]))

    base_ce = eval_ce(params)
    calib_batches = [pipe.get_batch(2000 + i)
                     for i in range(args.calib_batches)]
    if _MESH.get("data", 1) > 1:
        mesh = make_mesh((_MESH["data"],), ("data",))
        cal = calibrate_sharded(model, params, calib_batches, mesh,
                                axis="data")
        print(f"# sharded calibration: data={_MESH['data']} "
              f"(butterfly TSQR reduce)")
    else:
        cal = calibrate_model(model, params, calib_batches)
    if args.numerics_report:
        # duck-typed over Calibrator / ShardedCalibration: the same check
        # covers single-device and butterfly-reduced mesh calibration
        health = numerics.check_calibration(cal)
        print("# calibration numerics")
        print(numerics.format_report(health))
    ccfg = CompressConfig(method=args.method, ratio=args.ratio, lam=args.lam,
                          mu=args.mu, use_rsvd=args.rsvd)
    cparams, reports = compress_model(model, params, cal, ccfg)
    if args.numerics_report:
        print("# projection residual vs attainable bound")
        print(numerics.format_report(numerics.check_compression(reports)))
    s = compression_summary(reports)
    s.update(method=args.method, base_ce=base_ce, compressed_ce=eval_ce(cparams))
    print(json.dumps(s, indent=1))
    if args.ckpt_out:
        CheckpointManager(args.ckpt_out).save(0, {"params": cparams})
        print("saved to", args.ckpt_out)
    if args.trace_out:
        n = obs_trace.save(args.trace_out)
        print(f"wrote {n} trace events to {args.trace_out}")


if __name__ == "__main__":
    main()
