"""Production mesh construction (as a function — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
