import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes and record memory/cost/collective analysis for §Dry-run / §Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm_135m \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all        # resumable sweep

Results: experiments/dryrun/<arch>__<shape>__<mesh>[__variant].json
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import SHAPES, TrainConfig
from repro.configs import ARCH_IDS, get_config
from repro.dist.sharding import (batch_axes_of, batch_specs, cache_specs,
                                 param_specs, to_named, train_state_specs)
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.common import ParallelCtx
from repro.models.transformer import period_specs
from repro.roofline import analysis as roofline
from repro.roofline import jaxpr_cost
from repro.train.train_loop import make_train_step, make_train_state

ASSIGNED = [a for a in ARCH_IDS if a not in ("llama3_1b", "mistral_7b")]

# long_500k needs sub-quadratic attention: run only for SSM/hybrid/local-attn
LONG_OK = {"xlstm_1_3b", "jamba_v0_1_52b", "gemma2_27b"}


def cells():
    for arch in ASSIGNED:
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if shape == "long_500k" and arch not in LONG_OK:
                continue
            yield arch, shape


def make_ctx(cfg, mesh, *, mlstm_chunkwise: bool = False) -> ParallelCtx:
    return ParallelCtx(
        mesh=mesh, batch_axes=batch_axes_of(mesh),
        shard_map_moe=cfg.uses_moe,
        dense_attn_max_seq=2048, attn_chunk_q=2048, attn_chunk_kv=1024,
        mlstm_chunkwise=mlstm_chunkwise)


def auto_microbatches(cfg, shape_cfg, mesh) -> int:
    """Pick grad-accum so the scan-saved residual stream fits ~2GB/device."""
    n_shards = 1
    for a in batch_axes_of(mesh):
        n_shards *= mesh.shape[a]
    b_loc = max(1, shape_cfg.global_batch // n_shards)
    if cfg.family == "encdec":
        n_rep = cfg.n_layers + (cfg.n_enc_layers or cfg.n_layers)
    else:
        _, _, n_rep = period_specs(cfg)
    carry_bytes = b_loc * shape_cfg.seq_len * cfg.d_model * 2 * n_rep
    # chunked-attention backward keeps ~one layer's score blocks resident:
    # b x kv_heads_local x T^2 x 4B (heads shard over model only if divisible)
    s_model = mesh.shape.get("model", 1)
    h_loc = (cfg.n_kv_heads // s_model if cfg.n_kv_heads % s_model == 0
             else cfg.n_kv_heads)
    att_bytes = (b_loc * h_loc * shape_cfg.seq_len * shape_cfg.seq_len * 4
                 if cfg.family != "ssm" else 0)
    target = 2 << 30
    mb = 1
    while max(carry_bytes, att_bytes) / mb > target and mb < b_loc:
        mb *= 2
    return mb


def tokens_sds(cfg, shape_cfg, kind: str) -> Dict[str, jax.ShapeDtypeStruct]:
    b, t = shape_cfg.global_batch, shape_cfg.seq_len
    out = {}
    if kind == "decode":
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        return out
    t_text = t - (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    out["tokens"] = jax.ShapeDtypeStruct((b, t_text), jnp.int32)
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        out["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    return out


def abstract_factorize(params_sds, cfg, ratio: float):
    """COALA-compressed parameter skeleton: replace large dense linears with
    (b_t, a_t) factor pairs at the given kept-parameter ratio."""
    from repro.core.compress import compressible, rank_for_ratio_dims
    import jax.tree_util as jtu

    def walk(tree, path=()):
        if isinstance(tree, dict):
            if "w" in tree and compressible(path, tree["w"].shape, cfg):
                w = tree["w"]
                d_in, d_out = w.shape[-2], w.shape[-1]
                r = rank_for_ratio_dims(d_in, d_out, ratio)
                lead = w.shape[:-2]        # stacked-layer dim for scanned blocks
                return {"b_t": jax.ShapeDtypeStruct(lead + (d_in, r), w.dtype),
                        "a_t": jax.ShapeDtypeStruct(lead + (r, d_out), w.dtype)}
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v, path + (str(i),)) for i, v in enumerate(tree)]
        return tree

    return walk(params_sds)


def lower_cell(arch: str, shape: str, mesh_name: str, *,
               compress_ratio: float = 0.0, grad_compress: bool = False,
               zero: str = "fsdp", remat: str = "full",
               mlstm_chunkwise: bool = False) -> dict:
    cfg = get_config(arch)
    shape_cfg = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    ctx = make_ctx(cfg, mesh, mlstm_chunkwise=mlstm_chunkwise)
    model = build_model(cfg)
    t0 = time.time()

    if shape_cfg.kind == "train":
        mb = auto_microbatches(cfg, shape_cfg, mesh)
        tcfg = TrainConfig(microbatches=mb, remat=remat,
                           grad_compress_pods=grad_compress)
        state_sds = jax.eval_shape(
            lambda k: make_train_state(model, tcfg, k), jax.random.PRNGKey(0))
        if grad_compress and "pod" in mesh.axis_names:
            from repro.train import grad_compress as gc
            state_sds["err"] = jax.eval_shape(
                lambda p: gc.init_error_state(p, mesh.shape["pod"]),
                state_sds["params"])
        batch_sds = tokens_sds(cfg, shape_cfg, "train")
        if zero == "zero1h":
            # fp32 master fully sharded; bf16 TP compute copy hoisted per step
            sspecs = train_state_specs(cfg, state_sds, mesh, strategy="fsdp")
            cspecs = param_specs(cfg, state_sds["params"], mesh, mode="infer")
            step = make_train_step(model, tcfg, ctx, mesh=mesh,
                                   compute_specs=cspecs)
        else:
            sspecs = train_state_specs(cfg, state_sds, mesh, strategy=zero)
            step = make_train_step(model, tcfg, ctx, mesh=mesh)
        bspecs = batch_specs(cfg, batch_sds, mesh)
        jitted = jax.jit(step,
                         in_shardings=(to_named(sspecs, mesh),
                                       to_named(bspecs, mesh)),
                         out_shardings=(to_named(sspecs, mesh), None),
                         donate_argnums=0)
        lowered = jitted.lower(state_sds, batch_sds)
        jcost = jaxpr_cost.trace_cost(step, state_sds, batch_sds)
        params_for_flops = state_sds["params"]
        meta = {"microbatches": mb, "remat": remat, "zero": zero,
                "grad_compress": grad_compress}
    else:
        params_sds = jax.eval_shape(
            lambda k: model.init(k, dtype=jnp.bfloat16), jax.random.PRNGKey(0))
        if compress_ratio > 0:
            params_sds = abstract_factorize(params_sds, cfg, compress_ratio)
        cache_sds = jax.eval_shape(
            lambda: model.init_cache(shape_cfg.global_batch,
                                     shape_cfg.seq_len, dtype=jnp.bfloat16))
        pspecs = param_specs(cfg, params_sds, mesh, mode="infer")
        cspecs = cache_specs(cfg, cache_sds, mesh)
        batch_sds = tokens_sds(cfg, shape_cfg, shape_cfg.kind)
        bspecs = batch_specs(cfg, batch_sds, mesh)

        if shape_cfg.kind == "prefill":
            def fn(params, batch, cache):
                kw = {k: v for k, v in batch.items() if k != "tokens"}
                if cfg.family == "encdec":
                    return model.prefill(params, batch["tokens"], cache,
                                         ctx=ctx, frames=kw["frames"])
                if cfg.family == "vlm":
                    return model.prefill(params, batch["tokens"], cache,
                                         ctx=ctx,
                                         vision_embeds=kw["vision_embeds"])
                return model.prefill(params, batch["tokens"], cache, ctx=ctx)
            jitted = jax.jit(
                fn,
                in_shardings=(to_named(pspecs, mesh), to_named(bspecs, mesh),
                              to_named(cspecs, mesh)),
                out_shardings=(None, to_named(cspecs, mesh)),
                donate_argnums=2)
            lowered = jitted.lower(params_sds, batch_sds, cache_sds)
            jcost = jaxpr_cost.trace_cost(fn, params_sds, batch_sds, cache_sds)
        else:
            def fn(params, tokens, cache, pos):
                return model.decode_step(params, tokens, cache, pos, ctx=ctx)
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                fn,
                in_shardings=(to_named(pspecs, mesh),
                              NamedSharding(mesh, P()),
                              to_named(cspecs, mesh),
                              NamedSharding(mesh, P())),
                out_shardings=(None, to_named(cspecs, mesh)),
                donate_argnums=2)
            lowered = jitted.lower(params_sds, batch_sds["tokens"],
                                   cache_sds, pos_sds)
            jcost = jaxpr_cost.trace_cost(fn, params_sds, batch_sds["tokens"],
                                          cache_sds, pos_sds)
        params_for_flops = params_sds
        meta = {"compress_ratio": compress_ratio}

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mf = roofline.model_flops(cfg, params_for_flops, shape_cfg)
    rf = roofline.analyze(compiled, arch=arch, shape=shape,
                          mesh_name=mesh_name,
                          n_devices=mesh.devices.size,
                          model_flops_global=mf, jaxpr_cost=jcost)
    out = rf.to_json()
    out.update(status="ok", t_lower_s=round(t_lower, 1),
               t_compile_s=round(t_compile, 1), meta=meta,
               param_count=roofline.count_params(params_for_flops))
    return out


def run_cell(arch, shape, mesh_name, out_dir, *, force=False,
             compress_ratio=0.0, grad_compress=False, tag="",
             zero="fsdp", remat="full", mlstm_chunkwise=False):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}__{shape}__{mesh_name}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, name + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            prev = json.load(f)
        if prev.get("status") == "ok":
            print(f"[skip] {name} (cached)")
            return prev
    print(f"[run ] {name} ...", flush=True)
    try:
        out = lower_cell(arch, shape, mesh_name,
                         compress_ratio=compress_ratio,
                         grad_compress=grad_compress, zero=zero, remat=remat,
                         mlstm_chunkwise=mlstm_chunkwise)
    except Exception as e:  # record the failure — it is a bug to fix
        out = {"status": "error", "arch": arch, "shape": shape,
               "mesh": mesh_name, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        print(f"[FAIL] {name}: {out['error']}", flush=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1, default=float)
    if out.get("status") == "ok":
        print(f"[ok  ] {name}: dom={out['dominant']} "
              f"tc={out['t_compute']:.4f}s tm={out['t_memory']:.4f}s "
              f"tl={out['t_collective']:.4f}s "
              f"frac={out['roofline_fraction']:.3f} "
              f"(compile {out['t_compile_s']}s)", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--compress-ratio", type=float, default=0.0)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--zero", default="fsdp", choices=["fsdp", "zero1", "zero1h"])
    ap.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    ap.add_argument("--mlstm-chunkwise", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.all:
        fails = 0
        for mesh_name in ("single", "multi"):
            for arch, shape in cells():
                out = run_cell(arch, shape, mesh_name, args.out_dir,
                               force=args.force)
                fails += out.get("status") != "ok"
        print(f"\nsweep done, failures: {fails}")
        raise SystemExit(1 if fails else 0)

    assert args.arch and args.shape, "--arch/--shape required without --all"
    out = run_cell(args.arch, args.shape, args.mesh, args.out_dir,
                   force=args.force, compress_ratio=args.compress_ratio,
                   grad_compress=args.grad_compress, tag=args.tag,
                   zero=args.zero, remat=args.remat,
                   mlstm_chunkwise=args.mlstm_chunkwise)
    raise SystemExit(0 if out.get("status") == "ok" else 1)


if __name__ == "__main__":
    main()
