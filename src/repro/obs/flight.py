"""Per-request flight recorder: bounded ring of lifecycle events + postmortem.

A serving engine under load is a black box exactly when you need it not to
be: a pool-invariant assertion in the chaos soak, a numerics ``fail``
verdict, or a recalibration gate rejection tells you *that* something went
wrong, never *which request did what* in the steps leading up to it. The
``FlightRecorder`` closes that gap with a fixed-capacity ring buffer of
per-request lifecycle events — submit, admit, prefix-hit length, prefill
bucket, first token, per-round speculative proposed/accepted, preemption,
fork, recalibration capture/swap/reject, finish/evict — each stamped with
the engine step index at which it happened.

Design constraints:

  * **Bounded memory.** The ring is a ``collections.deque(maxlen=capacity)``;
    a long-running engine holds at most ``capacity`` events and counts the
    rest in ``dropped``. The monotonic ``seq`` stamp survives drops, so
    event order (and gaps) stay reconstructible from the tail.
  * **Cheap when attached, free when not.** Call sites guard with
    ``if flight is not None``; a record is one dict build and a deque
    append under a lock (the lock matters only for the HTTP telemetry
    thread and recalib worker reading concurrently).
  * **Zero dependencies.** Stdlib only, like the rest of ``repro.obs``.

``dump()`` writes the postmortem bundle — ring tail, metrics snapshot,
engine config, span-trace tail — as strict JSON. The engine wires it to
its failure paths (step exceptions, recalib gate rejections), and
``tests/test_soak_serve.py`` dumps it when a pool invariant trips.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

# The event taxonomy (docs/observability.md holds the prose table). Kept as
# a frozenset so tests can assert recorded events stay inside it.
EVENT_TYPES = frozenset({
    "submit",           # request entered the waiting queue
    "admit",            # scheduler moved it into the running batch
    "prefix_hit",       # prompt tokens satisfied from the prefix cache
    "prefill",          # batched suffix prefill (with padded bucket size)
    "first_token",      # first generated token (TTFT point)
    "spec_round",       # one speculative draft+verify round (proposed/accepted)
    "preempt",          # evicted back to the waiting queue under pool pressure
    "fork",             # copy-on-write fork into a child request
    "recalib_capture",  # activations streamed into the traffic calibrator
    "recalib_swap",     # bound-cleared factor hot-swap applied
    "recalib_reject",   # solve attempt failed a readiness gate
    "finish",           # request completed; final stats attached
    "evict",            # pool pages released
    "step_exception",   # engine.step() raised; recorded before the dump
})


def _json_safe(obj):
    """Strict-JSON-ready copy: non-finite floats become None (a metrics
    snapshot can legally carry inf/nan — e.g. a clearance gauge before any
    data — but the bundle must parse everywhere)."""
    if isinstance(obj, float):
        return obj if obj == obj and abs(obj) != float("inf") else None
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    return obj


class FlightRecorder:
    """Bounded ring buffer of per-request lifecycle events.

    ``capacity`` bounds memory; ``dump_path`` is where :meth:`dump` writes
    the postmortem bundle unless overridden per call.
    """

    def __init__(self, capacity: int = 4096,
                 dump_path: str = "POSTMORTEM_serve.json"):
        if capacity <= 0:
            raise ValueError(f"flight recorder capacity must be > 0, "
                             f"got {capacity}")
        self.capacity = int(capacity)
        self.dump_path = dump_path
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=self.capacity)
        self._seq = 0
        self._step = -1          # -1 = before the first engine step
        self.dropped = 0

    # ------------------------------------------------------------- recording
    def begin_step(self, idx: int) -> None:
        """Stamp subsequent events with engine step ``idx`` (the engine
        calls this at the top of ``step()``; scheduler/pool records made
        inside the step inherit it without plumbing)."""
        self._step = int(idx)

    @property
    def step(self) -> int:
        return self._step

    def record(self, event: str, req_id: Optional[str] = None,
               **fields: Any) -> None:
        """Append one event; oldest entry drops once past capacity."""
        with self._lock:
            ev: Dict[str, Any] = {"seq": self._seq, "step": self._step,
                                  "t": time.perf_counter(), "event": event}
            if req_id is not None:
                ev["req_id"] = req_id
            if fields:
                ev.update(fields)
            self._seq += 1
            if len(self._ring) == self.capacity:
                self.dropped += 1
            self._ring.append(ev)

    # --------------------------------------------------------------- reading
    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def events(self) -> List[dict]:
        """Ring contents, oldest first."""
        with self._lock:
            return list(self._ring)

    def events_for(self, req_id: str) -> List[dict]:
        """All retained events for one request, in record order."""
        return [e for e in self.events() if e.get("req_id") == req_id]

    # ------------------------------------------------------------ postmortem
    def dump(self, *, reason: str, metrics: Optional[dict] = None,
             config: Optional[dict] = None,
             path: Optional[str] = None) -> str:
        """Write the postmortem bundle as strict JSON; returns the path.

        Bundle contents: the failure ``reason``, the full ring tail (with
        ``seq``/``dropped`` so truncation is visible), the metrics snapshot
        and engine config the caller passes, and the tail of the active
        span trace when tracing is on.
        """
        from repro.obs import trace  # local import: avoid cycle at import time

        tracer = trace.current()
        trace_tail = tracer.tail(256) if tracer is not None else []
        bundle = {
            "reason": reason,
            "wallclock": time.time(),
            "capacity": self.capacity,
            "dropped": self.dropped,
            "next_seq": self._seq,
            "events": self.events(),
            "metrics": metrics if metrics is not None else {},
            "config": config if config is not None else {},
            "trace_tail": trace_tail,
        }
        out = path if path is not None else self.dump_path
        with open(out, "w") as f:
            # default=str: config values may be dtypes/paths; allow_nan off
            # keeps the bundle strict JSON for any downstream parser.
            json.dump(_json_safe(bundle), f, default=str, allow_nan=False)
        return out
