"""Span tracer emitting Chrome/Perfetto ``trace_event`` JSON.

One process-wide tracer records *complete* events (``ph: "X"``) around the
serving and calibration hot paths — scheduler admission, batched prefill,
decode steps, preemption, copy-on-write page copies, checkpoint I/O,
calibration R-factor accumulation, live-traffic recalibration
(``serve.recalib_capture/solve/check/swap``) — plus *instant* events
(``ph: "i"``) for jit compiles, prefix-cache evictions and rejected
recalibration solves. The output loads directly in
``chrome://tracing`` / https://ui.perfetto.dev.

Design constraints (docs/observability.md has the span taxonomy):

  * **Near-zero overhead when disabled.** Tracing is off by default; the
    module-level ``span()``/``instant()`` helpers check one global and
    return a shared no-op context manager, so an untraced hot path pays a
    function call and an attribute load — no allocation, no clock read.
  * **Thread-safe when enabled.** Spans carry the recording thread's id
    (checkpointing writes on a background thread) and the event list is
    appended under a lock; per-thread spans nest strictly because they
    come from ``with`` blocks on that thread.
  * **Zero dependencies.** Stdlib only: ``time.perf_counter`` timestamps
    (microseconds relative to ``enable()``), ``json`` on save.
  * **Bounded memory on demand.** ``enable(max_events=N)`` turns the event
    list into a ring (``deque(maxlen=N)``): long-running serving keeps the
    most recent N events and counts the rest in ``tracer.dropped``
    (``launch/serve.py --trace-max-events`` wires this).

Usage (the launchers wire ``--trace-out`` to this):

    from repro.obs import trace
    trace.enable()
    with trace.span("serve.decode_step", batch=4):
        ...
    trace.instant("serve.decode_compile", sig="(4, 8, True)")
    trace.save("trace.json")
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional


class _NullSpan:
    """Shared do-nothing context manager returned while tracing is off."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One recording ``with`` block: timestamps at enter, emits at exit."""

    __slots__ = ("_tracer", "_name", "_args", "_ts")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self) -> "_Span":
        self._ts = self._tracer._now_us()
        return self

    def __exit__(self, *exc) -> bool:
        t = self._tracer
        t._emit({"name": self._name, "ph": "X", "ts": self._ts,
                 "dur": t._now_us() - self._ts, "pid": t._pid,
                 "tid": threading.get_ident(),
                 **({"args": self._args} if self._args else {})})
        return False


class Tracer:
    """Collects trace events; ``save()`` writes Perfetto-loadable JSON."""

    def __init__(self, max_events: Optional[int] = None):
        self._lock = threading.Lock()
        # deque(maxlen=None) == unbounded append; a positive cap makes it a
        # ring holding the most recent events (bounded-memory serving)
        self._events: deque = deque(maxlen=max_events)
        self._t0 = time.perf_counter()
        self._pid = os.getpid()
        self.dropped = 0

    # ------------------------------------------------------------- recording
    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _emit(self, event: dict) -> None:
        with self._lock:
            if (self._events.maxlen is not None
                    and len(self._events) == self._events.maxlen):
                self.dropped += 1
            self._events.append(event)

    @property
    def max_events(self) -> Optional[int]:
        return self._events.maxlen

    def set_max_events(self, max_events: Optional[int]) -> None:
        """Re-cap the ring in place, keeping the newest events."""
        with self._lock:
            if max_events == self._events.maxlen:
                return
            old = list(self._events)
            if max_events is not None and len(old) > max_events:
                self.dropped += len(old) - max_events
            self._events = deque(old, maxlen=max_events)

    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        self._emit({"name": name, "ph": "i", "s": "t", "ts": self._now_us(),
                    "pid": self._pid, "tid": threading.get_ident(),
                    **({"args": args} if args else {})})

    def name_thread(self, name: str) -> None:
        """Label the calling thread's lane in the trace viewer (``M``
        metadata event) — background workers call this once at start so
        their spans render on a named track."""
        self._emit({"name": "thread_name", "ph": "M", "pid": self._pid,
                    "tid": threading.get_ident(), "args": {"name": name}})

    # --------------------------------------------------------------- output
    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def tail(self, n: int) -> List[dict]:
        """The most recent ``n`` events (postmortem bundles grab this)."""
        with self._lock:
            return list(self._events)[-n:] if n > 0 else []

    def save(self, path: str) -> int:
        """Write ``{"traceEvents": [...]}`` JSON; returns the event count."""
        with self._lock:
            events = list(self._events)
        doc = {"traceEvents": [
            {"name": "process_name", "ph": "M", "pid": self._pid, "tid": 0,
             "args": {"name": "repro"}},
            *events,
        ], "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(events)


# --------------------------------------------------------------------------
# Module-level singleton: call sites never thread a tracer object around.
# --------------------------------------------------------------------------

_TRACER: Optional[Tracer] = None


def enable(max_events: Optional[int] = None) -> Tracer:
    """Install (or return) the process tracer; spans record from now on.

    ``max_events`` caps the in-memory event list as a ring of the most
    recent events (``None`` = unbounded, the default). Re-enabling an
    existing tracer with an explicit cap re-caps it in place.
    """
    global _TRACER
    if _TRACER is None:
        _TRACER = Tracer(max_events=max_events)
    elif max_events is not None:
        _TRACER.set_max_events(max_events)
    return _TRACER


def disable() -> None:
    """Drop the tracer; ``span()``/``instant()`` become no-ops again."""
    global _TRACER
    _TRACER = None


def enabled() -> bool:
    return _TRACER is not None


def current() -> Optional[Tracer]:
    return _TRACER


def span(name: str, **args):
    """Context manager timing ``name``; free no-op when tracing is off."""
    t = _TRACER
    return t.span(name, **args) if t is not None else _NULL_SPAN


def instant(name: str, **args) -> None:
    """Point-in-time marker (compiles, evictions); no-op when off."""
    t = _TRACER
    if t is not None:
        t.instant(name, **args)


def name_thread(name: str) -> None:
    """Label the calling thread's trace lane; no-op when off."""
    t = _TRACER
    if t is not None:
        t.name_thread(name)


def save(path: str) -> int:
    """Write the active tracer's events to ``path``; 0 when tracing is off."""
    t = _TRACER
    return t.save(path) if t is not None else 0
