"""Live telemetry HTTP endpoints for a running ``ContinuousEngine``.

``--metrics-out`` and ``--trace-out`` only write at process exit, so a
live engine is a black box until it stops. ``TelemetryServer`` attaches a
stdlib ``http.server`` background thread to a running engine and serves:

  * ``GET /metrics``  — Prometheus text exposition straight from the
    engine's shared ``Registry`` (the same text ``tools/check_prom.py``
    lints in CI, now scraped mid-run);
  * ``GET /healthz``  — JSON health: *readiness* (warmup complete, or the
    first step has run on warmup-off engines) and *liveness* (``step()``
    progressed within ``step_deadline_s`` while work was pending).
    200 when ready and live, 503 otherwise;
  * ``GET /requests`` — JSON snapshot of in-flight request states
    (waiting + running: tokens in/out, cache length, preemptions, TTFT);
  * ``GET /snapshot`` — the ``engine.metrics()`` dict as strict JSON
    (``allow_nan=False`` — the zero-finished NaN fix makes this safe).

Design constraints:

  * **Zero dependencies, zero hot-path cost.** Stdlib ``http.server`` on
    a daemon thread; the serving loop never blocks on it. Reads take no
    engine locks — the registry tolerates torn reads by design, and the
    request snapshot copies list references before iterating.
  * **Engine is swappable.** ``attach()`` re-points the server at a new
    engine, so one server (one port) spans the dense → COALA engine
    sequence ``launch/serve.py`` runs back to back.
  * **Port 0 works.** Binding port 0 picks an ephemeral port, exposed as
    ``server.port`` — tests and benchmarks never race over a fixed one.
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional


def _request_view(req) -> dict:
    """JSON-safe summary of one scheduler ``Request``."""
    return {
        "req_id": req.req_id,
        "state": req.state,
        "prompt_tokens": int(len(req.prompt)),
        "out_tokens": len(req.out_tokens),
        "max_new_tokens": req.max_new_tokens,
        "cache_len": req.cache_len,
        "preemptions": req.preemptions,
        "spec_proposed": req.spec_proposed,
        "spec_accepted": req.spec_accepted,
        "ttft_s": req.ttft,
    }


class TelemetryServer:
    """Background HTTP server exposing a live engine's telemetry.

    ``port=0`` binds an ephemeral port (read ``server.port``). The engine
    may be attached at construction or later via :meth:`attach`; endpoints
    answer 503 until one is attached.
    """

    def __init__(self, engine=None, *, port: int = 0,
                 host: str = "127.0.0.1", step_deadline_s: float = 60.0):
        self._engine = engine
        self.step_deadline_s = float(step_deadline_s)
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *args) -> None:  # keep stdout clean
                pass

            def do_GET(self) -> None:
                outer._handle(self)

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = int(self._httpd.server_address[1])
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="telemetry", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- lifecycle
    def attach(self, engine) -> None:
        """Point the server at (a new) engine; safe while serving."""
        self._engine = engine

    def url(self, path: str = "") -> str:
        return f"http://{self.host}:{self.port}{path}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    # -------------------------------------------------------------- handlers
    def _handle(self, h: BaseHTTPRequestHandler) -> None:
        path = h.path.split("?", 1)[0]
        eng = self._engine
        try:
            if eng is None:
                self._send(h, 503, "application/json",
                           json.dumps({"error": "no engine attached"}))
            elif path == "/metrics":
                self._send(h, 200, "text/plain; version=0.0.4",
                           eng.registry.prometheus())
            elif path == "/healthz":
                body, code = self._healthz(eng)
                self._send(h, code, "application/json", body)
            elif path == "/requests":
                self._send(h, 200, "application/json", self._requests(eng))
            elif path == "/snapshot":
                self._send(h, 200, "application/json",
                           json.dumps(eng.metrics(), allow_nan=False))
            else:
                self._send(h, 404, "application/json",
                           json.dumps({"error": f"no such endpoint {path}"}))
        except Exception as e:  # a broken endpoint must not kill the thread
            try:
                self._send(h, 500, "application/json",
                           json.dumps({"error": repr(e)}))
            except Exception:
                pass

    def _healthz(self, eng):
        last = getattr(eng, "last_step_time", None)
        ready = bool(getattr(eng, "warmed", False) or last is not None)
        age = (time.perf_counter() - last) if last is not None else None
        has_work = eng.scheduler.has_work()
        # liveness: an idle engine is live by definition; one with pending
        # work must have stepped within the deadline (or not started yet)
        live = ((not has_work) or last is None
                or age < self.step_deadline_s)
        body = json.dumps({
            "ready": ready, "live": live, "has_work": has_work,
            "last_step_age_s": age,
            "waiting": len(eng.scheduler.waiting),
            "running": len(eng.scheduler.running),
        })
        return body, (200 if ready and live else 503)

    def _requests(self, eng) -> str:
        sched = eng.scheduler
        waiting = list(sched.waiting)
        running = list(sched.running)
        return json.dumps({
            "waiting": [_request_view(r) for r in waiting],
            "running": [_request_view(r) for r in running],
        })

    @staticmethod
    def _send(h: BaseHTTPRequestHandler, code: int, ctype: str,
              body) -> None:
        data = body.encode() if isinstance(body, str) else body
        h.send_response(code)
        h.send_header("Content-Type", ctype)
        h.send_header("Content-Length", str(len(data)))
        h.end_headers()
        h.wfile.write(data)
