"""Numerical-health monitors: make COALA's silent failure modes visible.

The paper's core claim is that context-aware compression fails *quietly*:
a near-singular activation Gram matrix doesn't crash anything — it just
degrades the projection (PAPER.md §1, Fig. 1), and the insufficient-data
regime is only safe when explicit bounds say enough calibration has been
seen. These monitors turn both into runtime observables:

  * **Condition number of each layer's streamed R factor** —
    ``triangular_cond`` estimates cond₁(R) from the triangular factor
    alone (one triangular solve, O(n³) on an n×n matrix that already
    exists): no Gram matrix is ever materialized, so the estimate itself
    cannot square the conditioning the way the Gram path does. cond(R) =
    cond(X), so this is the per-layer conditioning of the calibration
    data the projection will be weighted by.
  * **Insufficient data** — fewer calibration tokens than the layer's
    feature count leaves R rank-deficient (the paper's scenario (3));
    flagged from ``tokens_seen`` without touching the factor.
  * **Projection residual vs. the attainable bound** — each compressed
    layer's achieved ``‖(W−W')Rᵀ‖/‖WRᵀ‖`` against the theoretical
    minimum ``sqrt(Σ_{i>r} σ_i²(WRᵀ))/‖WRᵀ‖`` (core/theory.py's
    ``optimal_weighted_error``): a solver that silently lost accuracy
    shows up as residual ≫ bound even when nothing NaN'd.

``NumericsPolicy`` maps measurements to ``ok | warn | fail``; the default
thresholds (docs/observability.md) put *warn* at cond 1e6 (entrywise R
accuracy eroding in fp32) and *fail* at 1e8 (beyond ~1/eps₃₂ — Gram-based
baselines are numerically meaningless here and even the QR path's R is
only trustworthy up to an orthogonal factor). Surfaced through
``launch/compress.py --numerics-report``; works identically for the
single-device ``Calibrator`` and the sharded ``ShardedCalibration``
(both duck-type ``r_factors()`` / ``tokens_seen()``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

OK, WARN, FAIL = "ok", "warn", "fail"
_RANK = {OK: 0, WARN: 1, FAIL: 2}


@dataclasses.dataclass(frozen=True)
class NumericsPolicy:
    """Warn/fail thresholds (rationale + table in docs/observability.md)."""
    warn_cond: float = 1e6          # fp32 entrywise accuracy of R eroding
    fail_cond: float = 1e8          # past ~1/eps32: R trustworthy only up
    #                                 to an orthogonal factor; Gram paths dead
    min_token_factor: float = 1.0   # tokens_seen < factor * n => rank-
    #                                 deficient R (insufficient-data regime)
    warn_residual_excess: float = 2.0   # achieved residual vs attainable
    fail_residual_excess: float = 10.0  # bound: solver silently lost accuracy


@dataclasses.dataclass
class LayerHealth:
    """One layer's verdict; ``reasons`` carries the human-readable why."""
    path: str
    level: str                       # ok | warn | fail
    cond: float = float("nan")
    tokens: Optional[int] = None
    n: int = 0
    residual: float = float("nan")
    bound: float = float("nan")
    reasons: List[str] = dataclasses.field(default_factory=list)


def triangular_cond(r) -> float:
    """cond₁(R) of an upper-triangular (n, n) R — one triangular solve,
    no Gram materialization. Returns ``inf`` for a singular factor."""
    r = jnp.asarray(r, jnp.float32)
    n = r.shape[0]
    diag = jnp.abs(jnp.diagonal(r))
    if not bool(jnp.all(jnp.isfinite(r))) or float(diag.min()) == 0.0:
        return float("inf")
    rinv = solve_triangular(r, jnp.eye(n, dtype=r.dtype), lower=False)
    if not bool(jnp.all(jnp.isfinite(rinv))):
        return float("inf")
    norm1 = lambda a: float(jnp.abs(a).sum(axis=0).max())
    return norm1(r) * norm1(rinv)


def _grade(value: float, warn: float, fail: float) -> str:
    if not math.isfinite(value) or value >= fail:
        return FAIL
    return WARN if value >= warn else OK


def check_r_factors(r_factors: Dict[str, object],
                    tokens_seen: Optional[Dict[str, int]] = None,
                    policy: NumericsPolicy = NumericsPolicy()
                    ) -> List[LayerHealth]:
    """Grade every calibrated layer's R factor: conditioning + data volume."""
    out: List[LayerHealth] = []
    for path, r in r_factors.items():
        n = int(jnp.asarray(r).shape[0])
        cond = triangular_cond(r)
        tokens = tokens_seen.get(path) if tokens_seen else None
        level = _grade(cond, policy.warn_cond, policy.fail_cond)
        reasons = []
        if level != OK:
            reasons.append(
                f"cond(R)={cond:.2e} (warn>={policy.warn_cond:.0e}, "
                f"fail>={policy.fail_cond:.0e})")
        if tokens is not None and tokens < policy.min_token_factor * n:
            level = max(level, WARN, key=_RANK.get)
            reasons.append(
                f"insufficient data: {tokens} calibration tokens < "
                f"{policy.min_token_factor:g} x {n} features "
                f"(rank-deficient R)")
        out.append(LayerHealth(path=path, level=level, cond=cond,
                               tokens=tokens, n=n, reasons=reasons))
    return out


def check_augmented_r_factors(r_factors: Dict[str, object],
                              mus: Dict[str, float],
                              tokens_seen: Optional[Dict[str, int]] = None,
                              policy: NumericsPolicy = NumericsPolicy()
                              ) -> List[LayerHealth]:
    """Grade the μ-augmented factors R̃ = qr([R; √μ I]) — the matrices a
    regularized COALA solve actually uses (Prop. 3).

    In the insufficient-data regime the raw R is singular *by construction*
    (fewer streamed tokens than features), so ``check_r_factors`` would
    grade every such layer FAIL on conditioning forever. The μ-augmentation
    is exactly the paper's fix for that regime, and cond(R̃) is the
    conditioning of the problem being solved — the live recalibration gate
    (serve/recalibrate.py) grades this instead of refusing every
    under-streamed window outright. ``mus``: per-path μ actually used by
    the solve (LayerReport.mu); a path with μ <= 0 is graded raw. The
    insufficient-data reason still surfaces via ``tokens_seen``."""
    from repro.core.tsqr import augment_r_with_mu
    aug = {}
    for path, r in r_factors.items():
        mu = float(mus.get(path, 0.0))
        r = jnp.asarray(r, jnp.float32)
        aug[path] = augment_r_with_mu(r, mu) if mu > 0.0 else r
    return check_r_factors(aug, tokens_seen, policy)


def check_calibration(cal, policy: NumericsPolicy = NumericsPolicy()
                      ) -> List[LayerHealth]:
    """Health of a finished calibration — single-device ``Calibrator`` or
    mesh ``ShardedCalibration`` (both expose r_factors()/tokens_seen())."""
    return check_r_factors(cal.r_factors(), cal.tokens_seen(), policy)


def check_compression(reports, policy: NumericsPolicy = NumericsPolicy()
                      ) -> List[LayerHealth]:
    """Grade per-layer projection residuals against the attainable bound
    (``reports``: LayerReport list from core/compress.py, whose
    ``rel_err_bound`` is Σ-tail optimum of ‖(W−W')Rᵀ‖/‖WRᵀ‖)."""
    out: List[LayerHealth] = []
    for rep in reports:
        res, bound = rep.rel_err_weighted, getattr(rep, "rel_err_bound",
                                                   float("nan"))
        if not (math.isfinite(res) and math.isfinite(bound)):
            # per-expert fallback layers have no R factor; skip silently
            continue
        excess = res / max(bound, 1e-12)
        level = _grade(excess, policy.warn_residual_excess,
                       policy.fail_residual_excess)
        reasons = [] if level == OK else [
            f"residual {res:.3e} is {excess:.1f}x the attainable bound "
            f"{bound:.3e} (warn>={policy.warn_residual_excess:g}x)"]
        out.append(LayerHealth(path=rep.path, level=level, residual=res,
                               bound=bound, reasons=reasons))
    return out


def worst_level(healths: List[LayerHealth]) -> str:
    return max((h.level for h in healths), key=_RANK.get, default=OK)


def format_report(healths: List[LayerHealth], *, only_flagged: bool = False
                  ) -> str:
    """Fixed-width table + one WARN/FAIL line per flagged layer."""
    lines = [f"{'level':5}  {'cond(R)':>9}  {'tokens':>7}  "
             f"{'resid/bound':>12}  path"]
    n_flag = 0
    for h in sorted(healths, key=lambda h: (-_RANK[h.level], h.path)):
        if only_flagged and h.level == OK:
            continue
        ratio = (f"{h.residual / max(h.bound, 1e-12):10.1f}x"
                 if math.isfinite(h.residual) else f"{'-':>11}")
        cond = (f"{h.cond:9.2e}" if math.isfinite(h.cond)
                else f"{'-' if math.isnan(h.cond) else 'inf':>9}")
        tokens = f"{h.tokens}" if h.tokens is not None else "-"
        lines.append(f"{h.level:5}  {cond}  {tokens:>7}  {ratio:>12}  "
                     f"{h.path}")
        if h.level != OK:
            n_flag += 1
            lines.append(f"  NUMERICS {h.level.upper()} {h.path}: "
                         + "; ".join(h.reasons))
    lines.append(f"numerics: {len(healths)} layers checked, "
                 f"{n_flag} flagged, worst={worst_level(healths)}")
    return "\n".join(lines)
