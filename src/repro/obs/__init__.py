"""Observability: tracing, metrics, numerics monitors, live telemetry.

Five zero-dependency pillars (see docs/observability.md):

  * :mod:`repro.obs.trace` — Chrome/Perfetto ``trace_event`` spans around
    the serving/calibration hot paths (``--trace-out`` on the launchers;
    ``--trace-max-events`` caps the in-memory list as a ring);
  * :mod:`repro.obs.metrics` — Counter/Gauge/Histogram registry behind
    ``ContinuousEngine.metrics()``, with Prometheus exposition and JSON
    snapshots (``--metrics-out``);
  * :mod:`repro.obs.numerics` — per-layer R-factor condition monitoring
    and residual-vs-bound checks (``--numerics-report``);
  * :mod:`repro.obs.flight` — bounded per-request flight recorder and
    postmortem bundle dumps (``--flight-recorder``);
  * :mod:`repro.obs.server` — live HTTP telemetry endpoints ``/metrics``,
    ``/healthz``, ``/requests``, ``/snapshot`` (``--telemetry-port``).
"""
from repro.obs import flight, metrics, numerics, server, trace
from repro.obs.flight import EVENT_TYPES, FlightRecorder
from repro.obs.metrics import (LATENCY_BUCKETS, Counter, Gauge, Histogram,
                               Registry, log_buckets)
from repro.obs.numerics import (LayerHealth, NumericsPolicy,
                                check_calibration, check_compression,
                                check_r_factors, format_report,
                                triangular_cond, worst_level)
from repro.obs.server import TelemetryServer
from repro.obs.trace import Tracer

__all__ = [
    "trace", "metrics", "numerics", "flight", "server",
    "Counter", "Gauge", "Histogram", "Registry", "LATENCY_BUCKETS",
    "log_buckets",
    "NumericsPolicy", "LayerHealth", "check_calibration",
    "check_compression", "check_r_factors", "format_report",
    "triangular_cond", "worst_level", "Tracer",
    "FlightRecorder", "EVENT_TYPES", "TelemetryServer",
]
