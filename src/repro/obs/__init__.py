"""Observability: span tracing, typed metrics, numerical-health monitors.

Three zero-dependency pillars (see docs/observability.md):

  * :mod:`repro.obs.trace` — Chrome/Perfetto ``trace_event`` spans around
    the serving/calibration hot paths (``--trace-out`` on the launchers);
  * :mod:`repro.obs.metrics` — Counter/Gauge/Histogram registry behind
    ``ContinuousEngine.metrics()``, with Prometheus exposition and JSON
    snapshots (``--metrics-out``);
  * :mod:`repro.obs.numerics` — per-layer R-factor condition monitoring
    and residual-vs-bound checks (``--numerics-report``).
"""
from repro.obs import metrics, numerics, trace
from repro.obs.metrics import (LATENCY_BUCKETS, Counter, Gauge, Histogram,
                               Registry, log_buckets)
from repro.obs.numerics import (LayerHealth, NumericsPolicy,
                                check_calibration, check_compression,
                                check_r_factors, format_report,
                                triangular_cond, worst_level)
from repro.obs.trace import Tracer

__all__ = [
    "trace", "metrics", "numerics",
    "Counter", "Gauge", "Histogram", "Registry", "LATENCY_BUCKETS",
    "log_buckets",
    "NumericsPolicy", "LayerHealth", "check_calibration",
    "check_compression", "check_r_factors", "format_report",
    "triangular_cond", "worst_level", "Tracer",
]
