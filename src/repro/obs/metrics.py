"""Typed metrics registry: Counter / Gauge / Histogram, zero dependencies.

The serving and calibration subsystems register their observables here
instead of growing hand-rolled counter attributes: ``ContinuousEngine``,
``Scheduler`` and ``BlockPool`` all write into one shared ``Registry`` per
engine (``engine.registry``), and ``engine.metrics()`` is a compatibility
view over it. Two export formats:

  * ``Registry.prometheus()`` — Prometheus text exposition (validated by
    ``tools/check_prom.py``; written by ``launch/serve.py --metrics-out``);
  * ``Registry.snapshot()`` — flat ``{name: float}`` JSON-ready dict
    (histograms expand to ``_count/_sum/_mean/_p50/_p99/_max``), feeding
    ``benchmarks/run.py`` rows directly.

Histograms use **fixed log-spaced buckets** (``log_buckets``): serving
latencies (TTFT, inter-token/decode-step time, queue wait) span four-plus
decades, where linear buckets either saturate or lose the tail. Bucket
bounds are part of the metric's identity — fixed at registration so rows
stay comparable across runs and PRs.

Metric names follow Prometheus conventions (``snake_case``, counters end
in ``_total``, seconds-valued series end in ``_seconds``). The full name
table lives in docs/observability.md and is frozen by the golden-key
schema test in tests/test_obs.py; optional subsystems extend it only on
engines that enable them (``serve_spec_*`` with ``draft_params``,
``serve_recalib_*`` after ``attach_recalibrator``), so the base schema
never drifts.

Writers are the single-threaded serving loop; reads (exposition/snapshot)
may come from elsewhere and take no locks — a torn read costs one sample
of staleness, never corruption (floats and list slots update atomically
under the GIL).
"""
from __future__ import annotations

import math
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")


def log_buckets(lo: float, hi: float, per_decade: int = 3) -> Tuple[float, ...]:
    """Log-spaced histogram bounds covering [lo, hi], ``per_decade`` each."""
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got {lo}, {hi}")
    n = int(math.ceil(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10 ** (i / per_decade) for i in range(n + 1))


# TTFT / inter-token latency / queue wait all live in [0.1 ms, ~1 min] on
# every backend this repo targets; one shared bucket ladder keeps the
# latency histograms comparable to each other
LATENCY_BUCKETS = log_buckets(1e-4, 60.0, per_decade=3)


class Counter:
    """Monotonic accumulator (float-valued: also used for summed seconds)."""

    __slots__ = ("name", "help", "_value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        self._value += n

    @property
    def value(self) -> float:
        return self._value

    def reset(self) -> None:
        self._value = 0.0


class Gauge:
    """Point-in-time value: ``set()`` explicitly, or a callback (``fn``)
    evaluated at read time — pool/queue depths stay correct with no update
    plumbing through the hot path."""

    __slots__ = ("name", "help", "_value", "_fn")

    def __init__(self, name: str, help: str = "",
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.help = help
        self._value = 0.0
        self._fn = fn

    def set(self, v: float) -> None:
        if self._fn is not None:
            raise ValueError(f"gauge {self.name} is callback-backed")
        self._value = float(v)

    @property
    def value(self) -> float:
        return float(self._fn()) if self._fn is not None else self._value

    def reset(self) -> None:
        if self._fn is None:
            self._value = 0.0


class Histogram:
    """Fixed-bucket histogram (cumulative counts on exposition, like
    Prometheus ``le`` buckets; quantiles estimated from bucket edges)."""

    __slots__ = ("name", "help", "buckets", "_counts", "_sum", "_count",
                 "_max")

    def __init__(self, name: str, buckets: Sequence[float] = LATENCY_BUCKETS,
                 help: str = ""):
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b <= a for a, b in zip(bounds, bounds[1:])):
            raise ValueError(f"histogram {name}: buckets must be "
                             f"non-empty and increasing, got {bounds}")
        self.name = name
        self.help = help
        self.buckets = bounds
        self._counts = [0] * (len(bounds) + 1)   # last = overflow (+Inf)
        self._sum = 0.0
        self._count = 0
        self._max = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for b in self.buckets:
            if v <= b:
                break
            i += 1
        self._counts[i] += 1
        self._sum += v
        self._count += 1
        if v > self._max:
            self._max = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    @property
    def max(self) -> float:
        return self._max

    def quantile(self, q: float) -> float:
        """Upper bucket edge holding the q-quantile (0 with no samples;
        capped at the observed max for the overflow bucket)."""
        if not self._count:
            return 0.0
        target = q * self._count
        cum = 0
        for i, c in enumerate(self._counts):
            cum += c
            if cum >= target and c:
                edge = (self.buckets[i] if i < len(self.buckets)
                        else self._max)
                return min(edge, self._max)
        return self._max

    def reset(self) -> None:
        self._counts = [0] * (len(self.buckets) + 1)
        self._sum = 0.0
        self._count = 0
        self._max = 0.0


class Registry:
    """Ordered collection of typed metrics with exposition/snapshot/reset.

    Registration is strict: a duplicate name raises (metric identity drift
    is a bug, not a merge), and names must be Prometheus-legal.
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _register(self, m):
        if not _NAME_RE.match(m.name):
            raise ValueError(f"bad metric name {m.name!r}")
        if m.name in self._metrics:
            raise ValueError(f"metric {m.name!r} already registered")
        self._metrics[m.name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter(name, help))

    def gauge(self, name: str, help: str = "",
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        return self._register(Gauge(name, help, fn))

    def histogram(self, name: str,
                  buckets: Sequence[float] = LATENCY_BUCKETS,
                  help: str = "") -> Histogram:
        return self._register(Histogram(name, buckets, help))

    def get(self, name: str):
        return self._metrics[name]

    def names(self) -> List[str]:
        return list(self._metrics)

    def reset(self) -> None:
        """Zero counters/histograms/set-gauges (callback gauges read live
        state and are untouched) — the steady-state benchmarking hook
        behind ``ContinuousEngine.reset_metrics()``."""
        for m in self._metrics.values():
            m.reset()

    # ---------------------------------------------------------------- export
    def snapshot(self) -> Dict[str, float]:
        """Flat JSON-ready view; histogram ``h`` expands to ``h_count``,
        ``h_sum``, ``h_mean``, ``h_p50``, ``h_p99``, ``h_max``."""
        out: Dict[str, float] = {}
        for name, m in self._metrics.items():
            if isinstance(m, Histogram):
                out[f"{name}_count"] = float(m.count)
                out[f"{name}_sum"] = m.sum
                out[f"{name}_mean"] = m.mean
                out[f"{name}_p50"] = m.quantile(0.50)
                out[f"{name}_p99"] = m.quantile(0.99)
                out[f"{name}_max"] = m.max
            else:
                out[name] = m.value
        return out

    def prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: List[str] = []
        for name, m in self._metrics.items():
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name} {_fmt(m.value)}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {_fmt(m.value)}")
            else:
                lines.append(f"# TYPE {name} histogram")
                cum = 0
                for bound, c in zip(m.buckets, m._counts):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{_fmt(bound)}"}} {cum}')
                cum += m._counts[-1]
                lines.append(f'{name}_bucket{{le="+Inf"}} {cum}')
                lines.append(f"{name}_sum {_fmt(m.sum)}")
                lines.append(f"{name}_count {m.count}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    """Exposition-friendly number: integral floats print as ints."""
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)
