"""Deterministic synthetic data pipeline with exact step-indexed resume.

Every batch is a pure function of (seed, step) — restarting from a
checkpoint at step N reproduces the identical token stream with no state to
persist beyond the step counter. The token source is a learnable mixture:
with prob ~0.85 the next token is an affine map of the current one (plus a
slowly-varying per-stream offset), otherwise uniform noise — small models
reliably reach CE well below the uniform baseline, which the training tests
assert.

``calibration_stream`` yields activation-capture batches for the COALA
pipeline (same determinism guarantees).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 256
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    noise: float = 0.15


def _batch_tokens(dcfg: DataConfig, step: int) -> jax.Array:
    key = jax.random.fold_in(jax.random.PRNGKey(dcfg.seed), step)
    k_init, k_noise, k_mask, k_off = jax.random.split(key, 4)
    b, t, v = dcfg.global_batch, dcfg.seq_len, dcfg.vocab_size
    x0 = jax.random.randint(k_init, (b,), 0, v)
    offset = jax.random.randint(k_off, (b,), 0, 7)

    def gen(x, inp):
        k_n, k_m = inp
        nxt = (x * 3 + 7 + offset) % v
        noise = jax.random.randint(k_n, (b,), 0, v)
        use_noise = jax.random.bernoulli(k_m, dcfg.noise, (b,))
        nxt = jnp.where(use_noise, noise, nxt)
        return nxt, nxt

    keys_n = jax.random.split(k_noise, t - 1)
    keys_m = jax.random.split(k_mask, t - 1)
    _, rest = jax.lax.scan(gen, x0, (keys_n, keys_m))
    return jnp.concatenate([x0[None], rest], axis=0).T.astype(jnp.int32)


class TokenPipeline:
    """get_batch(step) -> {"tokens": (B, T) int32, ...extras per family}."""

    def __init__(self, dcfg: DataConfig, model_cfg=None):
        self.dcfg = dcfg
        self.model_cfg = model_cfg
        self._gen = jax.jit(lambda s: _batch_tokens(dcfg, s))

    def get_batch(self, step: int) -> Dict[str, jax.Array]:
        batch = {"tokens": self._gen(step)}
        cfg = self.model_cfg
        if cfg is not None and cfg.family == "encdec":
            key = jax.random.fold_in(jax.random.PRNGKey(self.dcfg.seed + 1), step)
            batch["frames"] = jax.random.normal(
                key, (self.dcfg.global_batch, cfg.n_audio_frames, cfg.d_model),
                jnp.float32)
        if cfg is not None and cfg.family == "vlm":
            key = jax.random.fold_in(jax.random.PRNGKey(self.dcfg.seed + 2), step)
            batch["vision_embeds"] = jax.random.normal(
                key, (self.dcfg.global_batch, cfg.n_vision_tokens, cfg.d_model),
                jnp.float32)
        return batch

    def iter_from(self, step: int) -> Iterator[Dict[str, jax.Array]]:
        while True:
            yield self.get_batch(step)
            step += 1


def calibration_stream(dcfg: DataConfig, n_batches: int):
    """Deterministic calibration batches (for activation capture)."""
    pipe = TokenPipeline(dcfg)
    for i in range(n_batches):
        yield pipe.get_batch(10_000_000 + i)     # disjoint from train stream
