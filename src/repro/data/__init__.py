from repro.data.pipeline import DataConfig, TokenPipeline, calibration_stream  # noqa: F401
