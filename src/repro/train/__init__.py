from repro.train.train_loop import make_train_step, make_train_state, cast_for_compute  # noqa: F401
from repro.train.optimizer import adamw_init, adamw_update, lr_at  # noqa: F401
