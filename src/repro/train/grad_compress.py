"""Two-level gradient reduction for multi-pod training.

Within a pod the data axis reduces gradients in full precision — ICI is fast
(GSPMD reduce-scatter from FSDP). ACROSS pods (DCN / optical links, ~10x
slower) we reduce int8-quantized gradients with **error feedback**:

    q_t  = quant(g_t + e_{t-1})
    ĝ_t  = mean_pods(dequant(q_t))
    e_t  = (g_t + e_{t-1}) - dequant(q_t)       # residual kept on the pod

Error feedback telescopes the quantization bias across steps, which is what
keeps convergence intact (EF-SGD, Karimireddy et al. 2019). Quantization is
per-block(128) symmetric int8 with an fp32 scale — ~4x fewer cross-pod bytes.

Structure (two phases, keeping the model OUT of any manual region):

  1. the batch reshapes to a leading pod axis ``(n_pods, B/n_pods, ...)``
     sharded ``P('pod', ...)`` and the loss+grad runs under ``jax.vmap``
     over that axis — per-pod gradients come out with an explicit leading
     pod dim instead of being fused into backward's pod reduction, while
     the data/model axes stay ordinary GSPMD code;
  2. ONLY the quantize → psum → dequantize reduction runs inside a
     ``shard_map`` that is manual over the ``pod`` axis. Its body is
     elementwise math plus one ``psum`` — the only shapes the pinned XLA
     can partition inside a manual subgroup (a ``scan``, i.e. any real
     model, inside partial-manual shard_map trips a fatal
     ``IsManualSubgroup`` check in the pinned partitioner).

The error state carries an explicit leading pod axis (spec ``P('pod', ...)``)
so each pod's residual survives round-trips through the global value.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

BLOCK = 128


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8. Returns (int8 payload, fp32 scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        # concatenate, not jnp.pad: a Pad HLO inside the pod-manual
        # shard_map region trips a fatal IsManualSubgroup check in the
        # pinned XLA partitioner; Concatenate partitions fine
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def quantized_mean_leaf(g: jax.Array, err: jax.Array, axis_name: str):
    """One leaf: error-feedback int8 psum-mean over ``axis_name``."""
    target = g.astype(jnp.float32) + err
    q, scale = _quantize(target)
    local_deq = _dequantize(q, scale, g.shape)
    new_err = target - local_deq
    size = jax.lax.axis_size(axis_name)
    g_hat = jax.lax.psum(local_deq, axis_name) / size
    return g_hat.astype(g.dtype), new_err


def init_error_state(params, n_pods: int):
    """fp32 residuals with an explicit leading pod axis."""
    return jax.tree.map(
        lambda x: jnp.zeros((n_pods,) + x.shape, jnp.float32), params)


def error_state_specs(params):
    return jax.tree.map(lambda _: P("pod"), params)


def make_compressed_grads_fn(loss_and_grad_fn: Callable, mesh,
                             batch_spec_fn: Callable):
    """Wraps ``loss_and_grad_fn(params, batch) -> ((loss, metrics), grads)``
    into a pod-manual region with int8+EF cross-pod gradient reduction.

    Returns ``f(params, batch, err) -> (loss, metrics, grads, new_err)``.
    """

    from jax.sharding import NamedSharding

    n_pods = mesh.shape["pod"]

    def wrapped(params, batch, err):
        # ---- phase 1: per-pod grads via vmap over an explicit pod axis ----
        def to_pod_major(x, flat_spec):
            y = x.reshape((n_pods, x.shape[0] // n_pods) + x.shape[1:])
            spec = P("pod", None, *tuple(flat_spec)[1:])
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, spec))

        pbatch = jax.tree.map(
            lambda x: to_pod_major(x, batch_spec_fn(x)), batch)
        (loss_p, metrics_p), grads_p = jax.vmap(
            loss_and_grad_fn, in_axes=(None, 0))(params, pbatch)

        # ---- phase 2: int8+EF reduction, manual over pod only ------------
        flat_g, gdef = jax.tree.flatten(grads_p)
        flat_e = gdef.flatten_up_to(err)
        ng = len(flat_g)

        def body(*args):
            gs, es = args[:ng], args[ng:]
            outs = [quantized_mean_leaf(g[0], e[0], "pod")
                    for g, e in zip(gs, es)]
            return ([o[0] for o in outs], [o[1][None] for o in outs])

        in_specs = (tuple(P("pod") for _ in flat_g)
                    + tuple(P("pod") for _ in flat_e))
        out_specs = ([P() for _ in flat_g], [P("pod") for _ in flat_g])
        new_g_flat, new_e_flat = jax.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=tuple(out_specs),
            check_vma=False, axis_names={"pod"})(*flat_g, *flat_e)
        grads = gdef.unflatten(new_g_flat)
        new_err = gdef.unflatten(new_e_flat)

        loss = jnp.mean(loss_p)
        metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics_p)
        return (loss, metrics, grads, new_err)

    return wrapped


def simulate_roundtrip(g: jax.Array) -> jax.Array:
    """Single-device test helper: quantize→dequantize without a mesh."""
    q, s = _quantize(g)
    return _dequantize(q, s, g.shape).astype(g.dtype)
