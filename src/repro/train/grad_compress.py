"""Two-level gradient reduction for multi-pod training.

Within a pod the data axis reduces gradients in full precision — ICI is fast
(GSPMD reduce-scatter from FSDP). ACROSS pods (DCN / optical links, ~10x
slower) we reduce int8-quantized gradients with **error feedback**:

    q_t  = quant(g_t + e_{t-1})
    ĝ_t  = mean_pods(dequant(q_t))
    e_t  = (g_t + e_{t-1}) - dequant(q_t)       # residual kept on the pod

Error feedback telescopes the quantization bias across steps, which is what
keeps convergence intact (EF-SGD, Karimireddy et al. 2019). Quantization is
per-block(128) symmetric int8 with an fp32 scale — ~4x fewer cross-pod bytes.

Structure: the *entire* loss+grad computation runs inside a ``shard_map``
that is manual ONLY over the ``pod`` axis (``axis_names={'pod'}``); the
data/model axes stay automatic, so the body is ordinary GSPMD code. That is
what exposes per-pod gradients to compress — under plain pjit the pod
reduction is fused into backward and cannot be intercepted. The error state
carries an explicit leading pod axis (spec ``P('pod', ...)``) so each pod's
residual survives round-trips through the global value.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

BLOCK = 128


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8. Returns (int8 payload, fp32 scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def quantized_mean_leaf(g: jax.Array, err: jax.Array, axis_name: str):
    """One leaf: error-feedback int8 psum-mean over ``axis_name``."""
    target = g.astype(jnp.float32) + err
    q, scale = _quantize(target)
    local_deq = _dequantize(q, scale, g.shape)
    new_err = target - local_deq
    size = jax.lax.axis_size(axis_name)
    g_hat = jax.lax.psum(local_deq, axis_name) / size
    return g_hat.astype(g.dtype), new_err


def init_error_state(params, n_pods: int):
    """fp32 residuals with an explicit leading pod axis."""
    return jax.tree.map(
        lambda x: jnp.zeros((n_pods,) + x.shape, jnp.float32), params)


def error_state_specs(params):
    return jax.tree.map(lambda _: P("pod"), params)


def make_compressed_grads_fn(loss_and_grad_fn: Callable, mesh,
                             batch_spec_fn: Callable):
    """Wraps ``loss_and_grad_fn(params, batch) -> ((loss, metrics), grads)``
    into a pod-manual region with int8+EF cross-pod gradient reduction.

    Returns ``f(params, batch, err) -> (loss, metrics, grads, new_err)``.
    """

    def wrapped(params, batch, err):
        flat_params, pdef = jax.tree.flatten(params)
        flat_batch, bdef = jax.tree.flatten(batch)
        flat_err, edef = jax.tree.flatten(err)
        np_, nb = len(flat_params), len(flat_batch)

        def body(*args):
            ps = pdef.unflatten(list(args[:np_]))
            bs = bdef.unflatten(list(args[np_:np_ + nb]))
            es = edef.unflatten(list(args[np_ + nb:]))
            es = jax.tree.map(lambda e: e[0], es)          # drop local pod dim
            (loss, metrics), grads = loss_and_grad_fn(ps, bs)
            flat_g, gdef = jax.tree.flatten(grads)
            flat_e2 = gdef.flatten_up_to(es)
            outs = [quantized_mean_leaf(g, e, "pod")
                    for g, e in zip(flat_g, flat_e2)]
            new_g = gdef.unflatten([o[0] for o in outs])
            new_e = gdef.unflatten([o[1][None] for o in outs])
            loss = jax.lax.pmean(loss, "pod")
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
            return (loss, metrics, new_g, new_e)

        in_specs = (tuple(P() for _ in flat_params)        # pod-replicated
                    + tuple(batch_spec_fn(b) for b in flat_batch)
                    + tuple(P("pod") for _ in flat_err))
        out_specs = (P(),
                     jax.tree.map(lambda _: P(), {"ce": 0, "aux": 0}),
                     jax.tree.map(lambda _: P(), params),
                     jax.tree.map(lambda _: P("pod"), params))
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False,
                             axis_names={"pod"})(
            *flat_params, *flat_batch, *flat_err)

    return wrapped


def simulate_roundtrip(g: jax.Array) -> jax.Array:
    """Single-device test helper: quantize→dequantize without a mesh."""
    q, s = _quantize(g)
    return _dequantize(q, s, g.shape).astype(g.dtype)
