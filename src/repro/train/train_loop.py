"""Training step builder: CE loss, grad accumulation, clipping, AdamW,
mixed precision, optional cross-pod gradient compression.

``make_train_step`` returns a pure function suitable for ``jax.jit`` with
explicit in/out shardings (the launcher and the dry-run both use it).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import TrainConfig
from repro.models.common import ParallelCtx
from repro.train import grad_compress as gc
from repro.train.optimizer import adamw_init, adamw_update, clip_by_global_norm


def cast_for_compute(params, dtype):
    """bf16 compute copies of the fp32 master weights (matrices only —
    norm vectors stay fp32 for stability)."""
    def cast(x):
        if x.ndim >= 2 and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(cast, params)


def make_train_state(model, tcfg: TrainConfig, key, param_dtype=jnp.float32):
    params = model.init(key, dtype=param_dtype)
    state = {"params": params, "opt": adamw_init(params)}
    if tcfg.grad_compress_pods:
        state["err"] = None  # filled by the launcher once n_pods is known
    return state


def make_train_step(model, tcfg: TrainConfig, ctx: ParallelCtx,
                    mesh=None, batch_leaf_spec=None, compute_specs=None):
    """``compute_specs``: optional PartitionSpec tree for a bf16 TP-sharded
    compute copy of the weights (ZeRO-1 "hoisted cast"). When given, the
    fp32 master stays FSDP-sharded and is all-gathered ONCE per step in
    bf16 (outside the microbatch loop); per-microbatch gradients are taken
    w.r.t. the compute copy and accumulated in fp32 — one bf16 all-gather +
    one fp32 reduce-scatter per step instead of per microbatch."""
    compute_dtype = jnp.dtype(tcfg.compute_dtype)

    def loss_and_grad(params, batch):
        def loss_fn(p):
            pc = cast_for_compute(p, compute_dtype)
            return model.loss(pc, batch, ctx=ctx, remat=tcfg.remat,
                              compute_dtype=compute_dtype)

        if tcfg.microbatches > 1:
            tokens = batch["tokens"]
            b = tokens.shape[0]
            mb = tcfg.microbatches
            assert b % mb == 0, (b, mb)

            def split(x):
                return x.reshape(mb, b // mb, *x.shape[1:])

            mbatch = {k: split(v) for k, v in batch.items()}

            def mb_loss(p, mbb):
                pc = cast_for_compute(p, compute_dtype)
                return model.loss(pc, mbb, ctx=ctx, remat=tcfg.remat,
                                  compute_dtype=compute_dtype)

            def body(carry, mbb):
                acc_g, acc_l, acc_a = carry
                (loss, metrics), grads = jax.value_and_grad(
                    mb_loss, has_aux=True)(params, mbb)
                acc_g = jax.tree.map(jnp.add, acc_g, grads)
                return (acc_g, acc_l + metrics["ce"], acc_a + metrics["aux"]), None

            zero_g = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                  params)
            (g, ce, aux), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32)), mbatch)
            g = jax.tree.map(lambda x: x / mb, g)
            metrics = {"ce": ce / mb, "aux": aux / mb}
            return (metrics["ce"] + metrics["aux"], metrics), g

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        return (loss, metrics), grads

    use_compress = (tcfg.grad_compress_pods and mesh is not None
                    and "pod" in mesh.axis_names)
    if use_compress:
        import dataclasses as _dc
        # the loss runs under vmap over the explicit pod axis (see
        # grad_compress.py): layout hints and nested shard_map regions do
        # not compose with that vmap on the pinned jax, so the per-pod body
        # drops them — GSPMD still auto-parallelizes over (data, model)
        ctx_pod = _dc.replace(ctx, batch_axes=(), shard_map_moe=False)

        def pod_loss_and_grad(params, batch):
            def loss_fn(p):
                pc = cast_for_compute(p, compute_dtype)
                return model.loss(pc, batch, ctx=ctx_pod, remat=tcfg.remat,
                                  compute_dtype=compute_dtype)
            return jax.value_and_grad(loss_fn, has_aux=True)(params)

        def batch_spec_fn(leaf):
            return P("pod", *([None] * (leaf.ndim - 1)))
        compressed = gc.make_compressed_grads_fn(pod_loss_and_grad, mesh,
                                                 batch_spec_fn)

    def hoisted_loss_and_grad(params, batch):
        from jax.sharding import NamedSharding
        pc = cast_for_compute(params, compute_dtype)
        pc = jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, s)),
            pc, compute_specs, is_leaf=lambda x: hasattr(x, "shape"))

        def mb_loss(p, mbb):
            return model.loss(p, mbb, ctx=ctx, remat=tcfg.remat,
                              compute_dtype=compute_dtype)

        mb = tcfg.microbatches
        if mb > 1:
            b = batch["tokens"].shape[0]
            assert b % mb == 0, (b, mb)
            mbatch = {k: v.reshape(mb, b // mb, *v.shape[1:])
                      for k, v in batch.items()}

            def body(carry, mbb):
                acc_g, ce, aux = carry
                (_, metrics), g = jax.value_and_grad(
                    mb_loss, has_aux=True)(pc, mbb)
                acc_g = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc_g, g)
                return (acc_g, ce + metrics["ce"], aux + metrics["aux"]), None

            zero_g = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                  pc)
            (g, ce, aux), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32)), mbatch)
            g = jax.tree.map(lambda x: x / mb, g)
            metrics = {"ce": ce / mb, "aux": aux / mb}
            return (metrics["ce"] + metrics["aux"], metrics), g
        (loss, metrics), g = jax.value_and_grad(mb_loss, has_aux=True)(
            pc, batch)
        g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
        return (loss, metrics), g

    def train_step(state, batch):
        params = state["params"]
        if use_compress:
            loss, metrics, grads, new_err = compressed(params, batch,
                                                       state["err"])
        elif compute_specs is not None and mesh is not None:
            (loss, metrics), grads = hoisted_loss_and_grad(params, batch)
            new_err = state.get("err")
        else:
            (loss, metrics), grads = loss_and_grad(params, batch)
            new_err = state.get("err")
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        new_params, new_opt, lr = adamw_update(tcfg, params, grads,
                                               state["opt"])
        new_state = {"params": new_params, "opt": new_opt}
        if "err" in state:
            new_state["err"] = new_err
        out_metrics = dict(metrics)
        out_metrics.update(loss=loss, grad_norm=gnorm, lr=lr)
        return new_state, out_metrics

    return train_step


# train-state spec assembly lives in repro.dist.sharding.train_state_specs
# (fsdp / zero1 / zero1h strategies) — the launchers and dry-run use that.
