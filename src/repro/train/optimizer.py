"""AdamW (fp32 master) + LR schedules (cosine, WSD, const) — no optax needed.

WSD (warmup–stable–decay) is MiniCPM's schedule [arXiv:2404.06395]: linear
warmup, long stable plateau, short (decay_frac) 1-sqrt-style decay tail.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


def lr_at(tcfg: TrainConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.asarray(tcfg.warmup_steps, jnp.float32)
    total = jnp.asarray(tcfg.total_steps, jnp.float32)
    base = jnp.asarray(tcfg.lr, jnp.float32)
    warm_lr = base * jnp.minimum(1.0, (step + 1) / jnp.maximum(warm, 1.0))
    if tcfg.schedule == "const":
        return warm_lr
    if tcfg.schedule == "cosine":
        t = jnp.clip((step - warm) / jnp.maximum(total - warm, 1.0), 0.0, 1.0)
        return jnp.where(step < warm, warm_lr,
                         base * 0.5 * (1.0 + jnp.cos(jnp.pi * t)))
    if tcfg.schedule == "wsd":
        decay_steps = jnp.maximum(total * tcfg.decay_frac, 1.0)
        decay_start = total - decay_steps
        t = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
        stable = base
        decayed = base * (1.0 - jnp.sqrt(t)) + base * 0.1 * jnp.sqrt(t)
        return jnp.where(step < warm, warm_lr,
                         jnp.where(step < decay_start, stable, decayed))
    raise ValueError(f"unknown schedule {tcfg.schedule}")


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def _decay_mask(path) -> bool:
    """Weight decay on matrices only (no norms/biases/vectors)."""
    return True


def adamw_update(tcfg: TrainConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, lr). All fp32 math."""
    step = opt_state["step"] + 1
    lr = lr_at(tcfg, step - 1)
    b1, b2, eps = tcfg.b1, tcfg.b2, tcfg.eps
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + eps)
        if p.ndim >= 2:
            delta = delta + tcfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, lr


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), norm
