"""Benchmark harness — one function per paper table/figure.

Prints ``name,value,notes`` CSV rows. CPU container: wall times are CPU BLAS
timings (relative ordering is the claim, as in the paper's Table 1/Fig. 3);
TPU-roofline numbers come from the dry-run (§Roofline), not from here.

  PYTHONPATH=src python -m benchmarks.run                  # all
  PYTHONPATH=src python -m benchmarks.run fig1 thm1        # subset
  PYTHONPATH=src python -m benchmarks.run serve --smoke \
      --json BENCH_serve.json                              # CI artifact
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

# --smoke shrinks the serving trace so the CI bench step stays ~1 min
SMOKE = False
ROWS: list = []


def _t(fn, repeat=3):
    fn()  # warmup/compile
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _row(name, value, notes=""):
    ROWS.append({"name": name, "value": value, "notes": notes})
    print(f"{name},{value},{notes}", flush=True)


def _ill_conditioned_x(n, k, cond=3e7, key=0):
    u = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(key), (n, n)))[0]
    v = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(key + 1), (k, n)))[0]
    s = jnp.logspace(0, -np.log10(cond), n).astype(jnp.float32)
    return (u * s[None, :]) @ v.T


# ---------------------------------------------------------------------------
# Figure 1: relative error vs rank, Gram-based vs QR-based (fp64 reference)
# ---------------------------------------------------------------------------

def fig1_stability():
    from repro.core import baselines, coala_project
    m, n, k = 96, 128, 256
    w = jax.random.normal(jax.random.PRNGKey(5), (m, n), jnp.float32)
    x = _ill_conditioned_x(n, k)
    w64, x64 = np.asarray(w, np.float64), np.asarray(x, np.float64)
    gram = x @ x.T
    for rank in (8, 16, 32, 64):
        u = np.linalg.svd(w64 @ x64)[0][:, :rank]
        ref = u @ u.T @ w64

        def rel(wa):
            wa = np.asarray(wa, np.float64)
            if not np.all(np.isfinite(wa)):
                return float("inf")
            return float(np.linalg.norm(wa - ref, 2) / np.linalg.norm(ref, 2))

        _row(f"fig1/coala_qr/r{rank}", f"{rel(coala_project(w, x, rank=rank)):.3e}")
        a, b = baselines.svd_llm(w, gram, rank)
        _row(f"fig1/svd_llm_cholesky/r{rank}", f"{rel(a @ b):.3e}",
             "NaN/inf = Cholesky failed (paper Fig.1 behaviour)")
        a, b = baselines.svd_llm_v2(w, gram, rank)
        _row(f"fig1/svd_llm_v2_gram/r{rank}", f"{rel(a @ b):.3e}")


# ---------------------------------------------------------------------------
# Figure 2: activation singular-value spectra (captured from a real forward)
# ---------------------------------------------------------------------------

def fig2_spectrum():
    from repro.configs import get_smoke_config
    from repro.core.calibrate import calibrate_model
    from repro.data import DataConfig, TokenPipeline
    from repro.models import build_model
    cfg = get_smoke_config("llama3_1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=4), cfg)
    cal = calibrate_model(model, params, [pipe.get_batch(i) for i in range(2)])
    for path, r in list(cal.r_factors().items())[:4]:
        s = np.linalg.svd(np.asarray(r), compute_uv=False)
        _row(f"fig2/sigma_ratio/{path.split('/')[-1]}",
             f"{s.min() / s.max():.3e}",
             f"sigma_max={s.max():.2e}")


# ---------------------------------------------------------------------------
# Table 1: compression wall time by strategy
# ---------------------------------------------------------------------------

def table1_timing():
    from repro.core import baselines, coala
    m, n, k = 512, 512, 16384
    w = jax.random.normal(jax.random.PRNGKey(0), (m, n), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (n, k), jnp.float32)
    rank = 128

    def run_coala():
        return coala.coala_project(w, x, rank=rank)

    def run_svdllm():
        g = x @ x.T
        a, b = baselines.svd_llm(w, g, rank)
        return a @ b

    def run_v2():
        g = x @ x.T
        a, b = baselines.svd_llm_v2(w, g, rank)
        return a @ b

    def run_coala_rsvd():
        return coala.coala_project(w, x, rank=rank, use_rsvd=True)

    for name, fn in (("coala_qr", run_coala), ("svd_llm", run_svdllm),
                     ("svd_llm_v2", run_v2), ("coala_rsvd", run_coala_rsvd)):
        _row(f"table1/{name}", f"{_t(fn) * 1e6:.0f}", "us_per_call (CPU)")


# ---------------------------------------------------------------------------
# Figure 3: R-factor via QR vs Gram; chunked TSQR vs chunked Gram
# ---------------------------------------------------------------------------

def fig3_qr_vs_gram():
    from repro.core import tsqr
    n = 256
    for k in (1024, 4096, 16384):
        x = jax.random.normal(jax.random.PRNGKey(k), (n, k), jnp.float32)
        qr_t = _t(lambda: tsqr.qr_r(x.T))
        gram_t = _t(lambda: jnp.linalg.cholesky(x @ x.T + 1e-6 * jnp.eye(n)))
        _row(f"fig3/qr_us/k{k}", f"{qr_t * 1e6:.0f}")
        _row(f"fig3/gram_chol_us/k{k}", f"{gram_t * 1e6:.0f}")
    x = jax.random.normal(jax.random.PRNGKey(9), (n, 16384), jnp.float32)
    for chunk in (1024, 4096):
        chunks = [x.T[i:i + chunk] for i in range(0, 16384, chunk)]
        t_tsqr = _t(lambda: tsqr.tsqr_sequential(chunks))
        _row(f"fig3/tsqr_us/chunk{chunk}", f"{t_tsqr * 1e6:.0f}",
             "streaming; never materializes X")


# ---------------------------------------------------------------------------
# Tables 2/3 analogue: compression quality by method on a trained model
# ---------------------------------------------------------------------------

_TRAINED = {}


def _trained_model():
    if _TRAINED:
        return _TRAINED["v"]
    from repro.config import TrainConfig
    from repro.configs import get_smoke_config
    from repro.core.calibrate import calibrate_model
    from repro.data import DataConfig, TokenPipeline
    from repro.models import build_model
    from repro.models.common import CPU_CTX
    from repro.train.train_loop import make_train_state, make_train_step
    cfg = get_smoke_config("llama3_1b")
    model = build_model(cfg)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=8, seed=11), cfg)
    tcfg = TrainConfig(lr=3e-3, warmup_steps=5, total_steps=120,
                       schedule="cosine", compute_dtype="float32")
    state = make_train_state(model, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tcfg, CPU_CTX))
    for i in range(120):
        state, _ = step(state, pipe.get_batch(i))
    params = state["params"]
    cal = calibrate_model(model, params, [pipe.get_batch(2000 + i)
                                          for i in range(4)])

    def eval_ce(p):
        return float(np.mean([float(model.loss(p, pipe.get_batch(1000 + i),
                                               compute_dtype=jnp.float32)[0])
                              for i in range(4)]))

    _TRAINED["v"] = (cfg, model, params, cal, eval_ce, pipe)
    return _TRAINED["v"]


def table2_compression_quality():
    from repro.config import CompressConfig
    from repro.core.compress import compress_model
    cfg, model, params, cal, eval_ce, _ = _trained_model()
    _row("table2/original_ce", f"{eval_ce(params):.4f}")
    ratio = 0.6
    for method, kw in (("asvd", {}), ("svd_llm", {}), ("svd", {}),
                       ("coala_mu0", dict(method="coala", mu=0.0)),
                       ("coala_mu", dict(method="coala", mu=-1.0, lam=4.0)),
                       ("coala_adaptive", dict(method="coala", mu=0.0,
                                               adaptive_rank=True))):
        ccfg = CompressConfig(method=kw.pop("method", method), ratio=ratio,
                              **kw)
        cp, _ = compress_model(model, params, cal, ccfg)
        _row(f"table2/{method}_ce@{ratio}", f"{eval_ce(cp):.4f}")


def fig5_lambda_sensitivity():
    from repro.config import CompressConfig
    from repro.core.compress import compress_model
    cfg, model, params, cal, eval_ce, _ = _trained_model()
    for lam in (0.5, 1.0, 4.0, 10.0, 40.0):
        cp, _ = compress_model(model, params, cal,
                               CompressConfig(method="coala", ratio=0.6,
                                              lam=lam, mu=-1.0))
        _row(f"fig5/ce@lam{lam}", f"{eval_ce(cp):.4f}",
             "paper: optimal lambda stable in [1;10]")


# ---------------------------------------------------------------------------
# Table 4 analogue: adapter-init methods, few fine-tuning steps
# ---------------------------------------------------------------------------

def table4_adapter_init():
    from repro.config import TrainConfig
    from repro.core.adapters import init_adapters, mask_grads
    from repro.data import DataConfig, TokenPipeline
    from repro.train.optimizer import adamw_init, adamw_update
    cfg, model, params, cal, eval_ce, _ = _trained_model()
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=8, seed=77), cfg)
    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=20,
                       schedule="const", weight_decay=0.0)
    for method in ("lora", "pissa", "corda", "coala_a1", "coala_a2"):
        ap, mask = init_adapters(params, cal.r_factors(), method=method,
                                 rank=8)
        opt = adamw_init(ap)

        @jax.jit
        def step(p, o, batch):
            def lf(p):
                return model.loss(p, batch, compute_dtype=jnp.float32)[0]
            loss, g = jax.value_and_grad(lf)(p)
            g = mask_grads(g, mask)
            p, o, _ = adamw_update(tcfg, p, g, o)
            return p, o, loss

        for i in range(20):
            ap, opt, loss = step(ap, opt, pipe.get_batch(i))
        _row(f"table4/{method}_ce_after_ft", f"{eval_ce(ap):.4f}")


# ---------------------------------------------------------------------------
# Theorem 1: ||W0 - W_mu|| linear in mu + bound
# ---------------------------------------------------------------------------

def thm1_convergence():
    from repro.core import coala_project, theory
    w = jax.random.normal(jax.random.PRNGKey(3), (48, 32), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (32, 12), jnp.float32)  # k<n
    r = 6
    w0 = coala_project(w, x, rank=r)
    errs, mus = [], (1e-2, 1e-3, 1e-4, 1e-5)
    for mu in mus:
        w_mu = coala_project(w, x, rank=r, mu=mu)
        diff = float(jnp.linalg.norm(w0 - w_mu))
        bound = float(theory.thm1_bound(w, x, r, mu))
        errs.append(diff)
        _row(f"thm1/err@mu{mu}", f"{diff:.3e}", f"bound={bound:.3e}")
    slope = np.polyfit(np.log(mus[:3]), np.log(np.maximum(errs[:3], 1e-12)),
                       1)[0]
    _row("thm1/loglog_slope", f"{slope:.2f}", "theory predicts ~1 (linear)")


# ---------------------------------------------------------------------------
# Kernel micro-bench (interpret mode on CPU — correctness path timing only)
# ---------------------------------------------------------------------------

def bench_kernels():
    from repro.kernels import ops, ref
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 512), jnp.float32)
    b_t = jax.random.normal(jax.random.PRNGKey(1), (512, 128), jnp.float32)
    a_t = jax.random.normal(jax.random.PRNGKey(2), (128, 512), jnp.float32)
    _row("kernels/lowrank_linear_us",
         f"{_t(lambda: ops.lowrank_linear(x, b_t, a_t)) * 1e6:.0f}",
         "interpret=True on CPU")
    _row("kernels/lowrank_ref_us",
         f"{_t(lambda: ref.lowrank_linear_ref(x, b_t, a_t)) * 1e6:.0f}")
    a = jax.random.normal(jax.random.PRNGKey(3), (2048, 256), jnp.float32)
    _row("kernels/gram_accum_us", f"{_t(lambda: ops.gram_accum(a)) * 1e6:.0f}")
    q = jax.random.normal(jax.random.PRNGKey(4), (1, 512, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(5), (1, 512, 2, 64), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(6), (1, 512, 2, 64), jnp.float32)
    _row("kernels/flash_attention_us",
         f"{_t(lambda: ops.flash_attention(q, k, v)) * 1e6:.0f}")


# ---------------------------------------------------------------------------
# Serving: continuous batching over the paged KV cache, dense vs compressed
# ---------------------------------------------------------------------------

def _decay_spectrum(params, rate):
    """Impose a geometric singular-value decay on every weight matrix.

    Random-init weights carry a flat singular spectrum, and a low-rank
    draft of a flat-spectrum matrix decorrelates from the target argmax
    almost immediately (near-zero acceptance). Trained LLM weight spectra
    decay fast — the regime COALA targets (PAPER.md §1) — so the
    speculative bench imposes ``sigma_i *= rate**i`` per matrix to
    reproduce that regime without a training run."""
    def walk(node):
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        if getattr(node, "ndim", 0) >= 2 and min(node.shape[-2:]) >= 32:
            arr = np.asarray(node, np.float32)
            u, s, vt = np.linalg.svd(arr, full_matrices=False)
            s = s * rate ** np.arange(s.shape[-1])
            return jnp.asarray((u * s[..., None, :]) @ vt, node.dtype)
        return node
    return walk(params)


def bench_serving():
    """Continuous batching on a mixed-length trace: the paged-attention
    kernel read path vs the gather-into-contiguous oracle (dense weights),
    plus dense vs COALA-compressed on the winning path. CPU wall times;
    relative ordering is the claim. Columns per variant: requests/sec,
    aggregate + steady-state decode tokens/sec, mean TTFT, and the decode
    recompile counter (bucketing keeps it ≤ the shape-bucket count). Also:
    prefix-cache on/off TTFT on a shared-prefix trace, chunked-prefill
    kernel vs gather suffix tok/s on a prefill-heavy trace, and
    speculative decoding (COALA self-draft) vs plain decode on a
    decode-heavy trace with decayed-spectrum weights. The JSON row schema
    is documented in docs/benchmarks.md."""
    from repro.config import CompressConfig
    from repro.configs import get_smoke_config
    from repro.core.calibrate import calibrate_model
    from repro.core.compress import compress_model
    from repro.data import DataConfig, TokenPipeline
    from repro.launch.serve import serve_trace, synthetic_trace
    from repro.models import build_model
    from repro.serve import ContinuousEngine
    cfg = get_smoke_config("smollm_135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=4), cfg)
    cal = calibrate_model(model, params, [pipe.get_batch(i) for i in range(2)])
    ccfg = CompressConfig(method="coala", ratio=0.6, lam=4.0, mu=-1.0)
    cparams, creports = compress_model(model, params, cal, ccfg)
    # skewed mixed lengths: long decodes + short joiners, so the bucketed
    # (B, pow2-blocks) envelope the gather path must materialize each step
    # well exceeds live pool usage — the padding the paged path never copies
    n_req, max_new, num_blocks = (10, 48, 40) if SMOKE else (16, 64, 48)
    trace = synthetic_trace(n_req, cfg.vocab_size, min_prompt=4,
                            max_prompt=24, max_new=max_new, arrival_every=3)

    def run(name, p, paged):
        # best-of-N on the steady-state decode rate (same spirit as _t's
        # min-of-3): single serves are noise-dominated on a shared CPU
        best = None
        for _ in range(2 if SMOKE else 3):
            eng = ContinuousEngine(model, p, compute_dtype=jnp.float32,
                                   cache_dtype=jnp.float32, block_size=8,
                                   num_blocks=num_blocks, max_running=4,
                                   paged_kernel=paged)
            m = serve_trace(eng, trace)
            if best is None or m["decode_tok_per_s"] > best["decode_tok_per_s"]:
                best = m
        m = best
        _row(f"serve/{name}_req_per_s", f"{m['requests_per_sec']:.3f}",
             "incl. compile")
        _row(f"serve/{name}_tok_per_s", f"{m['tokens_per_sec']:.2f}")
        _row(f"serve/{name}_decode_tok_per_s",
             f"{m['decode_tok_per_s']:.2f}", "steady-state (post-compile)")
        _row(f"serve/{name}_mean_ttft_s", f"{m['mean_ttft_s']:.3f}")
        _row(f"serve/{name}_decode_compiles", m["decode_compiles"],
             f"{m['decode_steps']} steps, {m['decode_shapes']} shape buckets")
        return m

    mg = run("gather", params, False)
    mp = run("paged", params, True)
    run("coala_paged", cparams, True)
    _row("serve/paged_vs_gather_decode_speedup",
         f"{mp['decode_tok_per_s'] / max(mg['decode_tok_per_s'], 1e-9):.3f}",
         "acceptance: >= 1.0")

    # prefix caching: system-prompt-heavy traffic (one long shared prefix,
    # short unique tails) served twice per variant — the first pass warms the
    # jit caches (and, with caching on, the block registry), the second is
    # the measured steady state, so the TTFT column compares prefix-hit
    # prefills against cold full-prompt prefills rather than compile noise
    pre_req = 10 if SMOKE else 14
    ptrace = synthetic_trace(pre_req, cfg.vocab_size, min_prompt=2,
                             max_prompt=6, shared_prefix=96, max_new=12,
                             arrival_every=2, seed=7)

    def steady_state(eng, trace, key, better):
        """One warm pass (jit compiles; with caching on, the registry too),
        then best-of-repeats on ``key`` (same spirit as _t's min-of-3: a
        single pass is noise-dominated on a shared CPU)."""
        serve_trace(eng, trace)
        m = None
        for _ in range(2 if SMOKE else 3):
            eng.reset_metrics()
            cur = serve_trace(eng, trace)
            if m is None or better(cur[key], m[key]):
                m = cur
        return m

    def run_prefix(name, on):
        eng = ContinuousEngine(model, params, compute_dtype=jnp.float32,
                               cache_dtype=jnp.float32, block_size=8,
                               num_blocks=160, max_running=4, prefix_cache=on)
        m = steady_state(eng, ptrace, "mean_ttft_s", lambda a, b: a < b)
        _row(f"serve/{name}_mean_ttft_s", f"{m['mean_ttft_s']:.4f}",
             "steady-state (warm jit, best of repeats)")
        _row(f"serve/{name}_cache_hit_rate", f"{m['prefix_hit_rate']:.3f}")
        _row(f"serve/{name}_prefill_compiles", m["prefill_compiles"],
             f"{m['prefill_batches']} batched prefill calls, "
             f"{m['prefill_shapes']} length buckets")
        return m

    mon = run_prefix("prefix_on", True)
    moff = run_prefix("prefix_off", False)
    _row("serve/prefix_cache_hit_rate", f"{mon['prefix_hit_rate']:.3f}",
         "acceptance: > 0")
    _row("serve/prefix_ttft_speedup",
         f"{moff['mean_ttft_s'] / max(mon['mean_ttft_s'], 1e-9):.3f}",
         "prefix-hit vs cold TTFT on the shared-prefix trace; "
         "acceptance: > 1.0")

    # chunked prefill: kernel vs gather on prefill-heavy traffic (long
    # prompts, short outputs). Prefix caching is off so every prompt token
    # rides the batched suffix-prefill path; one warm pass compiles, then
    # the steady-state suffix tok/s of the two read paths are compared.
    fp_req = 6 if SMOKE else 10
    ftrace = synthetic_trace(fp_req, cfg.vocab_size, min_prompt=24,
                             max_prompt=56, max_new=4, arrival_every=2,
                             seed=11)

    def run_prefill(name, kernel_on):
        eng = ContinuousEngine(model, params, compute_dtype=jnp.float32,
                               cache_dtype=jnp.float32, block_size=8,
                               num_blocks=160, max_running=4,
                               prefix_cache=False, prefill_kernel=kernel_on)
        m = steady_state(eng, ftrace, "prefill_tok_per_s",
                         lambda a, b: a > b)
        _row(f"serve/{name}_tok_per_s", f"{m['prefill_tok_per_s']:.1f}",
             "steady-state batched suffix prefill (warm jit, best of "
             "repeats)")
        _row(f"serve/{name}_mean_ttft_s", f"{m['mean_ttft_s']:.4f}")
        _row(f"serve/{name}_compiles", m["prefill_compiles"],
             f"{m['prefill_batches']} batched prefill calls, "
             f"{m['prefill_shapes']} length buckets")
        return m

    mk = run_prefill("prefill_kernel", True)
    mgp = run_prefill("prefill_gather", False)
    _row("serve/prefill_kernel_vs_gather_speedup",
         f"{mk['prefill_tok_per_s'] / max(mgp['prefill_tok_per_s'], 1e-9):.3f}",
         "chunked-prefill kernel vs gather oracle suffix tok/s; "
         "acceptance: >= 1.0")

    # warm start: an AOT-warmed engine vs a cold one on the same trace.
    # The config is deliberately tight (2 batch buckets, one prefill length
    # bucket, no prefix cache) so warmup() compiles a handful of signatures
    # rather than the full production cross-product — the claim is the
    # invariant (first-request TTFT at steady state, zero post-warmup
    # compiles), not warmup wall time. The offline row reuses the warmed
    # engine: the length-sorted batch lane's aggregate new tok/s.
    wtrace = synthetic_trace(6 if SMOKE else 10, cfg.vocab_size, min_prompt=4,
                             max_prompt=14, max_new=8, arrival_every=2,
                             seed=13)
    warm_len = max(len(p) + nn for _, p, nn in wtrace)
    wkw = dict(compute_dtype=jnp.float32, cache_dtype=jnp.float32,
               block_size=8, num_blocks=40, max_running=2,
               bucket_sizes=(1, 2), prefill_bucket_sizes=(32,),
               prefix_cache=False)

    def first_ttft(eng):
        return min(eng.finished, key=lambda r: r.req_id).ttft

    cold = ContinuousEngine(model, params, **wkw)
    serve_trace(cold, wtrace)
    _row("serve/cold_ttft_ms", f"{first_ttft(cold) * 1e3:.1f}",
         "first request on a cold engine (pays jit compiles)")
    warm = ContinuousEngine(model, params, **wkw)
    w = warm.warmup(max_len=warm_len)
    m = serve_trace(warm, wtrace)
    _row("serve/warm_ttft_ms", f"{first_ttft(warm) * 1e3:.1f}",
         "first request after warmup(); acceptance: < cold_ttft_ms")
    _row("serve/warmup_seconds", f"{w['warmup_seconds']:.2f}",
         f"{int(w['decode_signatures'])} decode + "
         f"{int(w['prefill_signatures'])} prefill signatures")
    _row("serve/post_warmup_compiles", m["post_warmup_compiles"],
         "acceptance: == 0 (every signature traffic hit was pre-compiled)")
    warm.reset_metrics()
    off_reqs = [(p, nn) for _, p, nn in wtrace]
    warm.run_offline(off_reqs)
    mo = warm.metrics()
    _row("serve/offline_tok_per_s", f"{mo['tokens_per_sec']:.2f}",
         "run_offline on the warmed engine: length-sorted, packed prefills")

    # observability overhead: the same paged-path trace with the FULL
    # telemetry plane on — span tracing, the live HTTP telemetry server
    # (bound on an ephemeral port, scraped once mid-measurement), a flight
    # recorder, and SLO accounting — vs everything off (the metrics
    # registry is always on; counters are plain attribute adds). Both
    # sides are steady-state best-of-repeats, like every serve row.
    import urllib.request

    from repro.obs import FlightRecorder, TelemetryServer
    from repro.obs import trace as obs_trace

    def mk_obs_engine(full_plane):
        flight = FlightRecorder(capacity=4096) if full_plane else None
        return ContinuousEngine(model, params, compute_dtype=jnp.float32,
                                cache_dtype=jnp.float32, block_size=8,
                                num_blocks=num_blocks, max_running=4,
                                paged_kernel=True,
                                slo_ttft_s=60.0 if full_plane else None,
                                slo_tpot_s=60.0 if full_plane else None,
                                flight_recorder=flight)

    # the two arms run INTERLEAVED and the overhead is the MEDIAN of the
    # per-round on/off ratios: a sequential A/B on a shared CPU measures
    # machine drift, not plane cost, and best-of-N still hands the win to
    # whichever arm drew the luckiest scheduling window (single-pass
    # deltas swing past the 5% bar in either direction)
    eng_off = mk_obs_engine(False)
    eng_on = mk_obs_engine(True)
    server = TelemetryServer(port=0)
    server.attach(eng_on)
    off = on = 0.0
    m_on = None
    ratios = []
    try:
        obs_trace.disable()
        serve_trace(eng_off, trace)                    # warm both jit sets
        obs_trace.enable()
        serve_trace(eng_on, trace)
        for _ in range(5 if SMOKE else 7):
            obs_trace.disable()
            eng_off.reset_metrics()
            r_off = serve_trace(eng_off, trace)["decode_tok_per_s"]
            off = max(off, r_off)
            obs_trace.enable()
            eng_on.reset_metrics()
            cur = serve_trace(eng_on, trace)
            if cur["decode_tok_per_s"] > on:
                on, m_on = cur["decode_tok_per_s"], cur
            ratios.append(cur["decode_tok_per_s"] / max(r_off, 1e-9))
        # prove the plane is actually live while we measure it
        with urllib.request.urlopen(server.url("/healthz"),
                                    timeout=10) as r:
            assert r.getcode() == 200, "/healthz not ready"
    finally:
        obs_trace.disable()
        server.close()
    assert len(eng_on.flight) > 0, "flight recorder saw no events"
    overhead_pct = (1.0 - float(np.median(ratios))) * 100.0
    _row("serve/obs_off_decode_tok_per_s", f"{off:.2f}",
         "telemetry plane fully off (no-op tracer singleton)")
    _row("serve/obs_on_decode_tok_per_s", f"{on:.2f}",
         "tracing + metrics + HTTP server + flight recorder + SLOs")
    _row("serve/obs_overhead_pct", f"{overhead_pct:.2f}",
         "acceptance: < 5 with the full telemetry plane enabled "
         "(median of per-round interleaved on/off throughput ratios)")
    _row("serve/slo_goodput", f"{m_on['slo_goodput']:.3f}",
         "fraction of finished requests inside generous 60s SLOs; "
         "acceptance: == 1.0 on uncontended smoke traffic")
    # latency-distribution rows straight from the registry snapshot — the
    # golden-key schema test (tests/test_obs.py) freezes these names
    snap = eng_on.registry.snapshot()
    for key in ("serve_ttft_seconds_p50", "serve_ttft_seconds_p99",
                "serve_queue_wait_seconds_p50",
                "serve_queue_wait_seconds_p99",
                "serve_decode_step_seconds_p50",
                "serve_decode_step_seconds_p99",
                "serve_tpot_seconds_p50", "serve_tpot_seconds_p99",
                "serve_request_e2e_seconds_p50",
                "serve_request_e2e_seconds_p99"):
        _row(f"serve/{key}", f"{snap[key]:.5f}", "registry snapshot")

    # speculative decoding: target + COALA self-draft built from the same
    # calibration pass (compress_model_pair), served from one engine. Two
    # things make this section's config deliberately different from the
    # rows above:
    #   * the model is scaled up (d_model 512, 4 layers) and the page pool
    #     over-provisioned (256 blocks, as a capacity-sized pool would be):
    #     at smoke dims every matmul is latency-bound and a draft step
    #     costs as much as a target step, so speculation has nothing to
    #     win. The regime it targets — and the one real serving sits in —
    #     is decode dominated by per-step cache/pool traffic, which the
    #     draft's gathered scan amortizes across k+1 proposals per round.
    #   * the served weights get the trained-LLM spectral decay
    #     (_decay_spectrum) first — on flat random-init weights any
    #     compressed draft decorrelates from the target argmax and
    #     acceptance is ~0.
    # Base and spec passes are interleaved (best-of-N each) so slow drift
    # on the shared CPU hits both sides equally.
    import dataclasses
    from repro.core.compress import compress_model_pair
    scfg = dataclasses.replace(cfg, d_model=512, n_heads=8, n_kv_heads=4,
                               d_ff=1536, n_layers=4)
    smodel = build_model(scfg)
    sparams = _decay_spectrum(smodel.init(jax.random.PRNGKey(0)), 0.9)
    spipe = TokenPipeline(DataConfig(vocab_size=scfg.vocab_size, seq_len=32,
                                     global_batch=4), scfg)
    scal = calibrate_model(smodel, sparams,
                           [spipe.get_batch(i) for i in range(2)])
    _, dparams, _, _ = compress_model_pair(
        smodel, sparams, scal,
        CompressConfig(method="coala", ratio=0.6, lam=4.0, mu=-1.0),
        draft_ratio=0.3)
    s_req, s_new = (8, 32) if SMOKE else (10, 40)
    strace = synthetic_trace(s_req, scfg.vocab_size, min_prompt=4,
                             max_prompt=16, min_new=s_new, max_new=s_new,
                             arrival_every=2, seed=17)
    warm_len = max(len(p) + nn for _, p, nn in strace)
    skw = dict(compute_dtype=jnp.float32, cache_dtype=jnp.float32,
               block_size=8, num_blocks=256, max_running=4,
               bucket_sizes=(4,), prefill_bucket_sizes=(16,),
               prefix_cache=False)

    base = ContinuousEngine(smodel, sparams, **skw)
    serve_trace(base, strace)                     # pass 1: compiles + parity
    spec = ContinuousEngine(smodel, sparams, draft_params=dparams, spec_k=4,
                            **skw)
    spec.warmup(max_len=warm_len)
    ms0 = serve_trace(spec, strace)               # pass 1: post-warmup count
    mb = ms = None
    for _ in range(4):
        base.reset_metrics()
        cur = serve_trace(base, strace)
        if mb is None or cur["decode_tok_per_s"] > mb["decode_tok_per_s"]:
            mb = cur
        spec.reset_metrics()
        cur = serve_trace(spec, strace)
        if ms is None or cur["decode_tok_per_s"] > ms["decode_tok_per_s"]:
            ms = cur

    def pass1_tokens(eng):
        fin = sorted(eng.finished, key=lambda r: r.req_id)[:len(strace)]
        return [list(r.out_tokens) for r in fin]

    parity = float(pass1_tokens(spec) == pass1_tokens(base))
    _row("serve/spec_baseline_tok_per_s", f"{mb['decode_tok_per_s']:.2f}",
         "non-speculative decode on the same decayed-spectrum target")
    _row("serve/spec_tok_per_s", f"{ms['decode_tok_per_s']:.2f}",
         "speculative emitted tok/s (COALA draft ratio 0.3, k=4)")
    _row("serve/spec_accept_rate", f"{ms['spec_accept_rate']:.3f}",
         "accepted / proposed draft tokens; acceptance: > 0")
    _row("serve/spec_decode_speedup",
         f"{ms['decode_tok_per_s'] / max(mb['decode_tok_per_s'], 1e-9):.3f}",
         "speculative vs plain decode tok/s, same trace; acceptance: >= 1.0")
    _row("serve/spec_greedy_parity", f"{parity:.1f}",
         "spec output token-exact vs non-spec at temperature 0; "
         "acceptance: == 1.0")
    _row("serve/spec_post_warmup_compiles", ms0["post_warmup_compiles"],
         "draft scan + verify join the warmed jit set; acceptance: == 0")

    # live-traffic recalibration: a sampled fraction of served activations
    # streams back into COALA calibration and, once the data/cond/bound
    # gates clear, rank-pinned recompressed factors hot-swap into the live
    # engine between steps. Rows:
    #   * greedy parity — an engine hot-swapping bitwise-identical factors
    #     every step emits exactly the tokens a never-swapped engine does
    #     (the value-swap no-op; in-flight requests keep their KV pages);
    #   * swaps / post_warmup_compiles — the real recalibration serve
    #     performs >= 1 bound-cleared swap with zero retraces after warmup
    #     (rank-stable shapes hit the live jit cache);
    #   * r_gram_rel_err — traffic-captured R equals an offline Calibrator
    #     fed the same sampled streams, as RᵀR (causal-replay parity).
    from repro.core.calibrate import Calibrator
    from repro.core.compress import rank_map_from_reports
    from repro.serve import RecalibPolicy, RecalibWorker, TrafficCalibrator
    rtrace = synthetic_trace(6, cfg.vocab_size, min_prompt=8, max_prompt=20,
                             max_new=16, arrival_every=2, seed=3)
    rkw = dict(compute_dtype=jnp.float32, cache_dtype=jnp.float32,
               block_size=8, num_blocks=64, max_running=4,
               bucket_sizes=(4,), prefix_cache=False)

    plain = ContinuousEngine(model, cparams, **rkw)
    serve_trace(plain, rtrace)
    ident = ContinuousEngine(model, cparams, **rkw)
    pending = list(rtrace)
    step = 0
    while pending or ident.has_work():
        while pending and pending[0][0] <= step:
            _, prompt, nn = pending.pop(0)
            ident.submit(prompt, nn)
        ident.step()
        if ident.scheduler.running:        # swap while requests in flight
            ident.hot_swap(jax.tree.map(jnp.copy, ident.params))
        step += 1
    ident.flush_stream()

    def out_tokens(eng):
        return [list(r.out_tokens)
                for r in sorted(eng.finished, key=lambda r: r.req_id)]

    _row("serve/recalib_greedy_parity",
         f"{float(out_tokens(ident) == out_tokens(plain)):.1f}",
         "per-step identity hot-swaps leave the token stream bit-exact; "
         "acceptance: == 1.0")

    reng = ContinuousEngine(model, cparams, **rkw)
    reng.warmup(max_len=max(len(p) + nn for _, p, nn in rtrace))
    tcal = TrafficCalibrator(
        model, policy=RecalibPolicy(check_every=1, min_new_tokens=16))
    worker = RecalibWorker(model, params, tcal, ccfg,
                           rank_map=rank_map_from_reports(creports))
    reng.attach_recalibrator(worker)
    mr = serve_trace(reng, rtrace)
    _row("serve/recalib_swaps", worker.swaps,
         f"bound-cleared hot-swaps over {worker.solve_attempts} solve "
         f"attempts ({tcal.captured_tokens} captured tokens); "
         "acceptance: >= 1")
    _row("serve/recalib_post_warmup_compiles", mr["post_warmup_compiles"],
         "rank-pinned factor swaps hit the warmed jit set; acceptance: == 0")
    _row("serve/recalib_swap_ms", f"{worker.last_swap_seconds * 1e3:.3f}",
         "wall time of the last hot_swap (validate + assign, no drain)")
    _row("serve/recalib_tokens_to_clearance", worker.tokens_at_first_swap,
         "captured tokens streamed before the first bound-cleared swap")

    offline = Calibrator()
    for stream in tcal.captured_streams:
        model.capture_forward(params, {"tokens": jnp.asarray(stream)[None]},
                              offline)
    rf_t, rf_o = tcal.r_factors(), offline.r_factors()
    gram_rel = max(
        float(jnp.linalg.norm(rf_t[p].T @ rf_t[p] - rf_o[p].T @ rf_o[p])
              / jnp.linalg.norm(rf_o[p].T @ rf_o[p]))
        for p in rf_o)
    _row("serve/recalib_r_gram_rel_err", f"{gram_rel:.2e}",
         "traffic R vs offline replay of the same streams, as R^T R; "
         "acceptance: < 1e-3")


# ---------------------------------------------------------------------------
# Distributed calibration: sharded vs single-device throughput + parity
# ---------------------------------------------------------------------------

def bench_dist():
    """Sharded (butterfly-TSQR) vs single-device COALA calibration.

    Runs in a subprocess with 8 fake host devices (the device count is
    locked at jax init, which already happened in this process). On the CPU
    container the per-shard capture loop is serialized on one host, so the
    sharded wall time is an upper bound — on a real mesh phase 1 runs
    per-host in parallel and only the butterfly reduce is on the wire. The
    parity row is the claim that matters: the distributed reduction changes
    the numbers by fp32 roundoff only. Row schema in docs/benchmarks.md.
    """
    import os
    import subprocess
    import sys
    n_batches = 2 if SMOKE else 4
    code = f"""
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.core.calibrate import calibrate_model
from repro.data import DataConfig, TokenPipeline
from repro.dist.calibrate import calibrate_sharded
cfg = get_smoke_config("smollm_135m")
from repro.models import build_model
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                global_batch=8, seed=5), cfg)
batches = [pipe.get_batch(i) for i in range({n_batches})]
tokens = sum(int(b["tokens"].size) for b in batches)
t0 = time.perf_counter(); single = calibrate_model(model, params, batches)
t_single = time.perf_counter() - t0
mesh = jax.make_mesh((8,), ("data",))
t0 = time.perf_counter()
sharded = calibrate_sharded(model, params, batches, mesh)
t_sharded = time.perf_counter() - t0
rs, rd = single.r_factors(), sharded.r_factors()
gram_rel = max(
    float(np.linalg.norm(np.asarray(rd[p]).T @ np.asarray(rd[p])
                         - np.asarray(rs[p]).T @ np.asarray(rs[p]))
          / np.linalg.norm(np.asarray(rs[p]).T @ np.asarray(rs[p])))
    for p in rs)
print("BENCH_JSON " + json.dumps(dict(
    tokens=tokens, t_single=t_single, t_sharded=t_sharded,
    gram_rel=gram_rel, layers=len(rs))))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH")) + \
        env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    if out.returncode != 0:
        # fail the CI bench step loudly — a quiet error row would keep the
        # step green with the acceptance row silently missing
        raise RuntimeError(
            f"dist benchmark subprocess failed:\n{out.stderr[-2000:]}")
    payload = json.loads(out.stdout.split("BENCH_JSON ", 1)[1])
    tok = payload["tokens"]
    _row("dist/calib_layers", payload["layers"], "captured linear layers")
    _row("dist/calib_single_tok_per_s", f"{tok / payload['t_single']:.1f}",
         "single-device Calibrator (streaming TSQR)")
    _row("dist/calib_sharded8_tok_per_s", f"{tok / payload['t_sharded']:.1f}",
         "8 data shards + butterfly reduce (CPU: shard loop serialized)")
    _row("dist/sharded_vs_single_ratio",
         f"{payload['t_single'] / payload['t_sharded']:.3f}",
         "wall-time ratio; >1 means sharded faster (expect ~1/shards on "
         "CPU, ~shards on a real mesh)")
    _row("dist/r_gram_rel_err", f"{payload['gram_rel']:.2e}",
         "max over layers of ||R_d^T R_d - R_s^T R_s||/||R_s^T R_s||; "
         "acceptance: < 1e-3")
    if not payload["gram_rel"] < 1e-3:        # enforced, not just printed
        raise RuntimeError(
            f"sharded-vs-single R parity regressed: gram_rel "
            f"{payload['gram_rel']:.2e} >= 1e-3")


# ---------------------------------------------------------------------------
# Roofline summary from the dry-run artifacts
# ---------------------------------------------------------------------------

def roofline_summary():
    import os
    from repro.roofline.report import load_results
    if not os.path.isdir("experiments/dryrun"):
        _row("roofline/skipped", "no experiments/dryrun directory")
        return
    res = [r for r in load_results() if r.get("status") == "ok"
           and r.get("mesh") == "single"]
    for r in res:
        tag = f"[{r['tag']}]" if r.get("tag") else ""
        _row(f"roofline/{r['arch']}/{r['shape']}{tag}",
             f"{r['roofline_fraction']:.4f}",
             f"dominant={r['dominant']}")


ALL = {
    "fig1": fig1_stability,
    "fig2": fig2_spectrum,
    "table1": table1_timing,
    "fig3": fig3_qr_vs_gram,
    "table2": table2_compression_quality,
    "fig5": fig5_lambda_sensitivity,
    "table4": table4_adapter_init,
    "thm1": thm1_convergence,
    "kernels": bench_kernels,
    "serve": bench_serving,
    "dist": bench_dist,
    "roofline": roofline_summary,
}


def main() -> None:
    global SMOKE
    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*",
                    help=f"benchmarks to run (default: all of {list(ALL)})")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink workloads for the CI smoke step")
    ap.add_argument("--json", metavar="PATH", default="",
                    help="also write rows as JSON (CI uploads BENCH_*.json "
                         "as a per-PR artifact)")
    args = ap.parse_args()
    SMOKE = args.smoke
    names = args.names or list(ALL)
    unknown = [n for n in names if n not in ALL]
    if unknown:
        ap.error(f"unknown benchmarks {unknown}; choose from {list(ALL)}")
    print("name,value,notes")
    # a suite that raises or emits zero rows fails the run (after every
    # requested suite has had its turn) — a hollow BENCH_*.json artifact
    # must never reach the perf gate looking like a green result
    errors: dict = {}
    for n in names:
        before = len(ROWS)
        try:
            ALL[n]()
        except Exception as e:                          # noqa: BLE001
            errors[n] = f"{type(e).__name__}: {e}"
            print(f"# ERROR {n}: {errors[n]}", flush=True)
        else:
            if len(ROWS) == before:
                errors[n] = "emitted no rows"
                print(f"# ERROR {n}: emitted no rows", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmarks": names, "smoke": SMOKE, "rows": ROWS,
                       "errors": errors}, f, indent=1)
        print(f"# wrote {args.json} ({len(ROWS)} rows)", flush=True)
    if errors:
        raise SystemExit(f"benchmark suites failed: {sorted(errors)}")


if __name__ == "__main__":
    main()
